//! Cross-process topic discovery for the TCP transport.
//!
//! One process (typically the one hosting the [`PipelineHub`]) runs a
//! [`NetRegistry`]; every publisher registers `topic → host:port` there
//! as it binds its data-plane listener, and every subscriber resolves
//! topics by name before connecting. The registry speaks the same
//! framed codec as the data plane ([`super::wire`]): `RegPut` /
//! `RegGet` requests, `RegAddr` responses (`None` = unknown topic).
//!
//! Registration is last-writer-wins on purpose: a publisher process
//! that died and was restarted (new ephemeral port) overwrites its
//! stale entry, which is what lets a reconnecting subscriber find the
//! new generation.
//!
//! [`PipelineHub`]: crate::pipeline::PipelineHub

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::thread::{self, JoinHandle};
use crate::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::net::wire::{read_msg, write_msg, Msg};
use crate::pipeline::executor::lock;

/// Per-operation I/O timeout on registry connections (both planes are
/// loopback/LAN; a stuck peer should fail typed, not hang a pipeline).
const REGISTRY_IO_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Default)]
struct RegistryState {
    topics: Mutex<HashMap<String, String>>,
    peers: Mutex<Vec<TcpStream>>,
    stopped: AtomicBool,
}

/// The discovery service. [`NetRegistry::serve`] returns a handle that
/// owns the listener; dropping the handle stops it.
pub struct NetRegistry;

impl NetRegistry {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve registry requests until the returned handle is dropped.
    pub fn serve(addr: &str) -> Result<RegistryServer> {
        let listener = TcpListener::bind(addr).map_err(|e| Error::Connect {
            topic: "<registry>".into(),
            addr: addr.to_string(),
            reason: e.to_string(),
        })?;
        let local = listener.local_addr()?;
        let state = Arc::new(RegistryState::default());
        let accept_state = Arc::clone(&state);
        let accept = thread::Builder::new()
            .name("nns-net-registry".into())
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    // `RegistryServer::drop` sets the flag, then makes a
                    // throwaway connection to pop this accept exactly once.
                    if accept_state.stopped.load(Ordering::Acquire) {
                        break;
                    }
                    let conn_state = Arc::clone(&accept_state);
                    if let Ok(peer) = stream.try_clone() {
                        lock(&conn_state.peers).push(peer);
                    }
                    conns.push(thread::spawn(move || serve_conn(stream, conn_state)));
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn registry accept thread");
        Ok(RegistryServer {
            addr: local,
            state,
            accept: Some(accept),
        })
    }
}

fn serve_conn(mut stream: TcpStream, state: Arc<RegistryState>) {
    let _ = stream.set_read_timeout(Some(REGISTRY_IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(REGISTRY_IO_TIMEOUT));
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match read_msg(&mut stream) {
            Ok(Some(m)) => m,
            // clean close, corrupt frame, or shutdown: drop the peer
            Ok(None) | Err(_) => break,
        };
        let reply = match msg {
            Msg::RegPut { topic, addr } => {
                lock(&state.topics).insert(topic, addr.clone());
                Msg::RegAddr { addr: Some(addr) }
            }
            Msg::RegGet { topic } => Msg::RegAddr {
                addr: lock(&state.topics).get(&topic).cloned(),
            },
            // data-plane messages on the registry port are a peer bug
            _ => break,
        };
        if write_msg(&mut stream, &reply).is_err() || stream.flush().is_err() {
            break;
        }
    }
}

/// Handle owning a running registry; dropping it stops the service.
pub struct RegistryServer {
    addr: SocketAddr,
    state: Arc<RegistryState>,
    accept: Option<JoinHandle<()>>,
}

impl RegistryServer {
    /// The bound address (resolves the ephemeral port of a `:0` bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Topics currently registered (diagnostics).
    pub fn topics(&self) -> Vec<(String, String)> {
        lock(&self.state.topics)
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

impl Drop for RegistryServer {
    fn drop(&mut self) {
        // Mark stopped, unblock the accept loop with a throwaway
        // connection, and sever live peers so their threads exit.
        self.state.stopped.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        for peer in lock(&self.state.peers).drain(..) {
            let _ = peer.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Client side of the discovery protocol. Stateless: each operation is
/// one short-lived connection, so a restarted registry (or publisher)
/// never wedges a cached socket.
#[derive(Debug, Clone)]
pub struct RegistryClient {
    addr: String,
}

impl RegistryClient {
    pub fn new(addr: impl Into<String>) -> RegistryClient {
        RegistryClient { addr: addr.into() }
    }

    /// The registry address this client talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn request(&self, topic: &str, req: &Msg) -> Result<Option<String>> {
        let connect_err = |reason: String| Error::Connect {
            topic: topic.to_string(),
            addr: self.addr.clone(),
            reason,
        };
        let mut stream =
            TcpStream::connect(&self.addr).map_err(|e| connect_err(e.to_string()))?;
        let _ = stream.set_read_timeout(Some(REGISTRY_IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(REGISTRY_IO_TIMEOUT));
        let _ = stream.set_nodelay(true);
        write_msg(&mut stream, req)?;
        stream.flush()?;
        match read_msg(&mut stream)? {
            Some(Msg::RegAddr { addr }) => Ok(addr),
            Some(other) => Err(connect_err(format!(
                "unexpected registry reply {other:?}"
            ))),
            None => Err(connect_err("registry closed without replying".into())),
        }
    }

    /// Register (or overwrite) `topic → addr`.
    pub fn put(&self, topic: &str, addr: &str) -> Result<()> {
        self.request(
            topic,
            &Msg::RegPut {
                topic: topic.to_string(),
                addr: addr.to_string(),
            },
        )?;
        Ok(())
    }

    /// Resolve `topic`; `Ok(None)` means the registry is reachable but
    /// the topic is not (yet) registered.
    pub fn get(&self, topic: &str) -> Result<Option<String>> {
        self.request(
            topic,
            &Msg::RegGet {
                topic: topic.to_string(),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_and_overwrite_roundtrip() {
        let server = NetRegistry::serve("127.0.0.1:0").expect("serve");
        let client = RegistryClient::new(server.addr().to_string());
        assert_eq!(client.get("ns/frames").unwrap(), None);
        client.put("ns/frames", "127.0.0.1:4000").unwrap();
        assert_eq!(
            client.get("ns/frames").unwrap().as_deref(),
            Some("127.0.0.1:4000")
        );
        // last-writer-wins: a restarted publisher overwrites its entry
        client.put("ns/frames", "127.0.0.1:4001").unwrap();
        assert_eq!(
            client.get("ns/frames").unwrap().as_deref(),
            Some("127.0.0.1:4001")
        );
        assert_eq!(server.topics().len(), 1);
    }

    #[test]
    fn unreachable_registry_is_a_typed_connect_error() {
        // bind-then-drop to learn a port that is certainly closed
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let client = RegistryClient::new(format!("127.0.0.1:{port}"));
        match client.get("ns/frames") {
            Err(Error::Connect { topic, .. }) => assert_eq!(topic, "ns/frames"),
            other => panic!("expected Connect error, got {other:?}"),
        }
    }

    #[test]
    fn server_stops_on_drop_and_port_closes() {
        let addr = {
            let server = NetRegistry::serve("127.0.0.1:0").expect("serve");
            let client = RegistryClient::new(server.addr().to_string());
            client.put("t", "a").unwrap();
            server.addr().to_string()
        };
        // after drop the port no longer accepts registry requests
        let client = RegistryClient::new(addr);
        assert!(client.get("t").is_err());
    }
}
