//! Versioned framed wire codec for the TCP tensor-query transport.
//!
//! Every message on a connection (and on a registry connection) is one
//! **frame**:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      0x4E4E5354 ("NNST", little-endian u32)
//!      4     1  version    1
//!      5     1  type       frame type code (Hello, Caps, Buffer, ...)
//!      6     2  flags      reserved, must be 0
//!      8     4  length     payload length in bytes
//!     12     4  checksum   FNV-1a (32-bit) over the payload bytes
//! ```
//!
//! All integers are little-endian. Decoders never panic on wire input:
//! truncated, corrupted, or inconsistent frames yield a typed
//! [`Error::Frame`]. Caps and tensor metadata are encoded **binary**
//! (tag bytes + fixed-width integers), not via the text `Caps` syntax —
//! the launch-line `Display`/`parse` pair is intentionally lossy
//! (`ANY`, audio sample counts) and must not constrain the wire.
//!
//! Buffer payloads are read **zero-copy into pool storage**: each
//! chunk's bytes go straight from the socket into a
//! [`ChunkPool`]-recycled allocation wrapped by [`Chunk::from_pooled`],
//! so a tensor crossing the wire costs one read syscall per chunk and
//! no intermediate copies.

use std::io::Read;

use crate::error::{Error, Fault, Result};
use crate::pipeline::Qos;
use crate::tensor::{
    AudioInfo, Buffer, Caps, Chunk, ChunkPool, DType, Dims, TensorInfo, VideoFormat, VideoInfo,
    MAX_TENSORS,
};

/// Frame magic: "NNST" read as a little-endian u32.
pub const MAGIC: u32 = 0x4E4E_5354;
/// Current protocol version.
pub const VERSION: u8 = 1;
/// Fixed frame-header size in bytes.
pub const HEADER_LEN: usize = 16;
/// Upper bound on a frame payload; larger advertised lengths are
/// treated as corruption instead of attempted as allocations.
pub const MAX_PAYLOAD: u32 = 1 << 30;

// Frame type codes.
const T_HELLO: u8 = 1;
const T_CAPS: u8 = 2;
const T_BUFFER: u8 = 3;
const T_EOS: u8 = 4;
const T_FAULT: u8 = 5;
const T_CREDIT: u8 = 6;
const T_DETACH: u8 = 7;
const T_REG_PUT: u8 = 8;
const T_REG_GET: u8 = 9;
const T_REG_ADDR: u8 = 10;

/// One wire message, either direction, data plane or registry plane.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Subscriber → publisher handshake: which topic, the subscriber's
    /// bounded queue capacity, the initial credit grant (capacity minus
    /// frames still queued from a previous connection generation), and
    /// the delivery QoS the publisher should apply on overflow.
    Hello {
        topic: String,
        capacity: u32,
        credits: u32,
        qos: Qos,
    },
    /// Publisher → subscriber: caps advertised on the topic.
    Caps(Caps),
    /// Publisher → subscriber: one tensor/media frame.
    Buffer(Buffer),
    /// Publisher → subscriber: clean end-of-stream.
    Eos,
    /// Publisher → subscriber: the stream was truncated by this fault.
    Fault(Fault),
    /// Subscriber → publisher: grant `n` more frame credits.
    Credit(u32),
    /// Subscriber → publisher: detaching; stop sending.
    Detach,
    /// Publisher → registry: `topic` is served at `addr`.
    RegPut { topic: String, addr: String },
    /// Subscriber → registry: where is `topic` served?
    RegGet { topic: String },
    /// Registry → subscriber: resolution result (`None` = unknown topic).
    RegAddr { addr: Option<String> },
}

impl Msg {
    fn type_code(&self) -> u8 {
        match self {
            Msg::Hello { .. } => T_HELLO,
            Msg::Caps(_) => T_CAPS,
            Msg::Buffer(_) => T_BUFFER,
            Msg::Eos => T_EOS,
            Msg::Fault(_) => T_FAULT,
            Msg::Credit(_) => T_CREDIT,
            Msg::Detach => T_DETACH,
            Msg::RegPut { .. } => T_REG_PUT,
            Msg::RegGet { .. } => T_REG_GET,
            Msg::RegAddr { .. } => T_REG_ADDR,
        }
    }
}

/// Incremental 32-bit FNV-1a.
struct Fnv(u32);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0x811c_9dc5)
    }

    fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= u32::from(b);
            h = h.wrapping_mul(0x0100_0193);
        }
        self.0 = h;
    }

    fn digest(&self) -> u32 {
        self.0
    }
}

fn frame_err(msg: impl Into<String>) -> Error {
    Error::Frame(msg.into())
}

// ---------------------------------------------------------------------
// Payload encoding
// ---------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    let bytes = s.as_bytes();
    if bytes.len() > u16::MAX as usize {
        return Err(frame_err(format!(
            "string of {} bytes exceeds the u16 wire limit",
            bytes.len()
        )));
    }
    put_u16(out, bytes.len() as u16);
    out.extend_from_slice(bytes);
    Ok(())
}

fn qos_code(q: Qos) -> u8 {
    match q {
        Qos::Blocking => 0,
        Qos::Leaky => 1,
        Qos::LatestOnly => 2,
    }
}

fn qos_from_code(c: u8) -> Result<Qos> {
    Ok(match c {
        0 => Qos::Blocking,
        1 => Qos::Leaky,
        2 => Qos::LatestOnly,
        other => return Err(frame_err(format!("unknown qos code {other}"))),
    })
}

fn dtype_code(t: DType) -> u8 {
    match t {
        DType::U8 => 0,
        DType::I8 => 1,
        DType::U16 => 2,
        DType::I16 => 3,
        DType::U32 => 4,
        DType::I32 => 5,
        DType::U64 => 6,
        DType::I64 => 7,
        DType::F32 => 8,
        DType::F64 => 9,
    }
}

fn dtype_from_code(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::U8,
        1 => DType::I8,
        2 => DType::U16,
        3 => DType::I16,
        4 => DType::U32,
        5 => DType::I32,
        6 => DType::U64,
        7 => DType::I64,
        8 => DType::F32,
        9 => DType::F64,
        other => return Err(frame_err(format!("unknown dtype code {other}"))),
    })
}

fn video_format_code(f: VideoFormat) -> u8 {
    match f {
        VideoFormat::Rgb => 0,
        VideoFormat::Bgr => 1,
        VideoFormat::Gray8 => 2,
        VideoFormat::Nv12 => 3,
    }
}

fn video_format_from_code(c: u8) -> Result<VideoFormat> {
    Ok(match c {
        0 => VideoFormat::Rgb,
        1 => VideoFormat::Bgr,
        2 => VideoFormat::Gray8,
        3 => VideoFormat::Nv12,
        other => return Err(frame_err(format!("unknown video format code {other}"))),
    })
}

fn put_tensor_info(out: &mut Vec<u8>, info: &TensorInfo) {
    out.push(dtype_code(info.dtype));
    let dims = info.dims.as_slice();
    out.push(dims.len() as u8);
    for &d in dims {
        put_u32(out, d as u32);
    }
}

fn put_caps(out: &mut Vec<u8>, caps: &Caps) -> Result<()> {
    match caps {
        Caps::Any => out.push(0),
        Caps::Video(v) => {
            out.push(1);
            out.push(video_format_code(v.format));
            put_u32(out, v.width as u32);
            put_u32(out, v.height as u32);
            put_u64(out, v.fps_millis);
        }
        Caps::Audio(a) => {
            out.push(2);
            put_u32(out, a.rate as u32);
            put_u32(out, a.channels as u32);
            put_u32(out, a.samples_per_buffer as u32);
        }
        Caps::Text => out.push(3),
        Caps::Tensor { info, fps_millis } => {
            out.push(4);
            put_tensor_info(out, info);
            put_u64(out, *fps_millis);
        }
        Caps::Tensors { infos, fps_millis } => {
            if infos.len() > MAX_TENSORS {
                return Err(frame_err(format!(
                    "caps with {} tensors exceed MAX_TENSORS {MAX_TENSORS}",
                    infos.len()
                )));
            }
            out.push(5);
            out.push(infos.len() as u8);
            for info in infos {
                put_tensor_info(out, info);
            }
            put_u64(out, *fps_millis);
        }
        Caps::FlatBuf => out.push(6),
    }
    Ok(())
}

/// Encode the payload of a **non-buffer** message. Buffer frames are
/// streamed by [`write_msg`] without materializing the payload.
fn encode_payload(msg: &Msg) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    match msg {
        Msg::Hello {
            topic,
            capacity,
            credits,
            qos,
        } => {
            put_str(&mut out, topic)?;
            put_u32(&mut out, *capacity);
            put_u32(&mut out, *credits);
            out.push(qos_code(*qos));
        }
        Msg::Caps(caps) => put_caps(&mut out, caps)?,
        Msg::Buffer(_) => unreachable!("buffer payloads are streamed"),
        Msg::Eos | Msg::Detach => {}
        Msg::Fault(fault) => {
            put_str(&mut out, &fault.element)?;
            put_str(&mut out, &fault.message)?;
            out.push(u8::from(fault.panicked));
        }
        Msg::Credit(n) => put_u32(&mut out, *n),
        Msg::RegPut { topic, addr } => {
            put_str(&mut out, topic)?;
            put_str(&mut out, addr)?;
        }
        Msg::RegGet { topic } => put_str(&mut out, topic)?,
        Msg::RegAddr { addr } => match addr {
            Some(a) => {
                out.push(1);
                put_str(&mut out, a)?;
            }
            None => out.push(0),
        },
    }
    Ok(out)
}

fn header(ty: u8, length: u32, checksum: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4] = VERSION;
    h[5] = ty;
    // flags (h[6..8]) reserved as 0
    h[8..12].copy_from_slice(&length.to_le_bytes());
    h[12..16].copy_from_slice(&checksum.to_le_bytes());
    h
}

/// Encode a full frame (header + payload) into one byte vector.
/// Buffer frames copy their payload here — use [`write_msg`] on the
/// send path; `encode` exists for tests and the registry plane.
pub fn encode(msg: &Msg) -> Result<Vec<u8>> {
    if let Msg::Buffer(buf) = msg {
        let meta = buffer_meta(buf)?;
        let mut len = meta.len();
        for c in &buf.chunks {
            len += 4 + c.len();
        }
        if len > MAX_PAYLOAD as usize {
            return Err(frame_err(format!(
                "buffer frame of {len} bytes exceeds MAX_PAYLOAD"
            )));
        }
        let mut fnv = Fnv::new();
        fnv.update(&meta);
        let mut body = Vec::with_capacity(HEADER_LEN + len);
        body.extend_from_slice(&[0u8; HEADER_LEN]); // patched below
        body.extend_from_slice(&meta);
        for c in &buf.chunks {
            let bytes = c.as_bytes();
            let chunk_len = (bytes.len() as u32).to_le_bytes();
            fnv.update(&chunk_len);
            fnv.update(bytes);
            body.extend_from_slice(&chunk_len);
            body.extend_from_slice(bytes);
        }
        let h = header(T_BUFFER, len as u32, fnv.digest());
        body[..HEADER_LEN].copy_from_slice(&h);
        return Ok(body);
    }
    let payload = encode_payload(msg)?;
    let mut fnv = Fnv::new();
    fnv.update(&payload);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&header(msg.type_code(), payload.len() as u32, fnv.digest()));
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Write one frame. Buffer payloads are streamed chunk-by-chunk (no
/// payload-sized intermediate allocation).
pub fn write_msg(w: &mut impl std::io::Write, msg: &Msg) -> Result<()> {
    if let Msg::Buffer(buf) = msg {
        let meta = buffer_meta(buf)?;
        let mut len = meta.len();
        // Borrow every chunk's bytes once: the same slices feed the
        // checksum pass and the write pass (one traffic-accounted read).
        let chunks: Vec<&[u8]> = buf.chunks.iter().map(|c| c.as_bytes()).collect();
        let mut fnv = Fnv::new();
        fnv.update(&meta);
        for bytes in &chunks {
            len += 4 + bytes.len();
            fnv.update(&(bytes.len() as u32).to_le_bytes());
            fnv.update(bytes);
        }
        if len > MAX_PAYLOAD as usize {
            return Err(frame_err(format!("buffer frame of {len} bytes exceeds MAX_PAYLOAD")));
        }
        w.write_all(&header(T_BUFFER, len as u32, fnv.digest()))?;
        w.write_all(&meta)?;
        for bytes in &chunks {
            w.write_all(&(bytes.len() as u32).to_le_bytes())?;
            w.write_all(bytes)?;
        }
        return Ok(());
    }
    let frame = encode(msg)?;
    w.write_all(&frame)?;
    Ok(())
}

fn buffer_meta(buf: &Buffer) -> Result<Vec<u8>> {
    if buf.chunks.len() > MAX_TENSORS {
        return Err(frame_err(format!(
            "buffer with {} chunks exceeds MAX_TENSORS {MAX_TENSORS}",
            buf.chunks.len()
        )));
    }
    let mut meta = Vec::with_capacity(25);
    put_u64(&mut meta, buf.pts_ns);
    put_u64(&mut meta, buf.duration_ns);
    put_u64(&mut meta, buf.seq);
    meta.push(buf.chunks.len() as u8);
    Ok(meta)
}

// ---------------------------------------------------------------------
// Payload decoding
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over a payload slice.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| frame_err("truncated payload"))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| frame_err("string payload is not valid UTF-8"))
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(frame_err(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn get_tensor_info(d: &mut Dec<'_>) -> Result<TensorInfo> {
    let dtype = dtype_from_code(d.u8()?)?;
    let rank = d.u8()? as usize;
    if rank == 0 || rank > crate::tensor::MAX_RANK {
        return Err(frame_err(format!("bad tensor rank {rank}")));
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        let v = d.u32()? as usize;
        if v == 0 {
            return Err(frame_err("zero tensor dimension"));
        }
        dims.push(v);
    }
    Ok(TensorInfo::new(dtype, Dims::new(&dims)))
}

fn get_caps(d: &mut Dec<'_>) -> Result<Caps> {
    Ok(match d.u8()? {
        0 => Caps::Any,
        1 => Caps::Video(VideoInfo {
            format: video_format_from_code(d.u8()?)?,
            width: d.u32()? as usize,
            height: d.u32()? as usize,
            fps_millis: d.u64()?,
        }),
        2 => Caps::Audio(AudioInfo {
            rate: d.u32()? as usize,
            channels: d.u32()? as usize,
            samples_per_buffer: d.u32()? as usize,
        }),
        3 => Caps::Text,
        4 => {
            let info = get_tensor_info(d)?;
            Caps::Tensor {
                info,
                fps_millis: d.u64()?,
            }
        }
        5 => {
            let n = d.u8()? as usize;
            if n > MAX_TENSORS {
                return Err(frame_err(format!(
                    "caps with {n} tensors exceed MAX_TENSORS {MAX_TENSORS}"
                )));
            }
            let mut infos = Vec::with_capacity(n);
            for _ in 0..n {
                infos.push(get_tensor_info(d)?);
            }
            Caps::Tensors {
                infos,
                fps_millis: d.u64()?,
            }
        }
        6 => Caps::FlatBuf,
        other => return Err(frame_err(format!("unknown caps tag {other}"))),
    })
}

fn decode_payload(ty: u8, payload: &[u8]) -> Result<Msg> {
    let mut d = Dec::new(payload);
    let msg = match ty {
        T_HELLO => Msg::Hello {
            topic: d.string()?,
            capacity: d.u32()?,
            credits: d.u32()?,
            qos: qos_from_code(d.u8()?)?,
        },
        T_CAPS => Msg::Caps(get_caps(&mut d)?),
        T_EOS => Msg::Eos,
        T_FAULT => Msg::Fault(Fault {
            element: d.string()?,
            message: d.string()?,
            panicked: d.u8()? != 0,
        }),
        T_CREDIT => Msg::Credit(d.u32()?),
        T_DETACH => Msg::Detach,
        T_REG_PUT => Msg::RegPut {
            topic: d.string()?,
            addr: d.string()?,
        },
        T_REG_GET => Msg::RegGet { topic: d.string()? },
        T_REG_ADDR => Msg::RegAddr {
            addr: match d.u8()? {
                0 => None,
                1 => Some(d.string()?),
                other => return Err(frame_err(format!("bad option tag {other}"))),
            },
        },
        other => return Err(frame_err(format!("unknown frame type {other}"))),
    };
    d.finish()?;
    Ok(msg)
}

/// Decode one full frame from a byte slice (tests, registry plane).
/// The slice must contain exactly one frame.
pub fn decode(frame: &[u8]) -> Result<Msg> {
    let mut cursor = frame;
    let msg = read_msg(&mut cursor)?.ok_or_else(|| frame_err("empty input"))?;
    if !cursor.is_empty() {
        return Err(frame_err(format!(
            "{} trailing bytes after frame",
            cursor.len()
        )));
    }
    Ok(msg)
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly **at a frame boundary**; EOF anywhere inside a frame is a
/// typed [`Error::Frame`]. I/O failures surface as [`Error::Io`].
pub fn read_msg(r: &mut impl Read) -> Result<Option<Msg>> {
    let mut head = [0u8; HEADER_LEN];
    // Distinguish boundary-EOF (no header byte at all) from truncation.
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut head[got..])? {
            0 if got == 0 => return Ok(None),
            0 => return Err(frame_err(format!("truncated header ({got} of {HEADER_LEN} bytes)"))),
            n => got += n,
        }
    }
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(frame_err(format!("bad magic {magic:#010x}")));
    }
    if head[4] != VERSION {
        return Err(frame_err(format!(
            "unsupported protocol version {} (this build speaks {VERSION})",
            head[4]
        )));
    }
    let ty = head[5];
    let flags = u16::from_le_bytes(head[6..8].try_into().unwrap());
    if flags != 0 {
        return Err(frame_err(format!("unknown flags {flags:#06x}")));
    }
    let length = u32::from_le_bytes(head[8..12].try_into().unwrap());
    if length > MAX_PAYLOAD {
        return Err(frame_err(format!(
            "payload length {length} exceeds MAX_PAYLOAD {MAX_PAYLOAD}"
        )));
    }
    let checksum = u32::from_le_bytes(head[12..16].try_into().unwrap());
    if ty == T_BUFFER {
        // Buffer payloads stream straight from the socket into pooled
        // chunk storage — no payload-sized intermediate allocation.
        return read_buffer_payload(r, length, checksum).map(Some);
    }
    let mut payload = ChunkPool::global().take(length as usize);
    read_payload_exact(r, &mut payload)?;
    let mut fnv = Fnv::new();
    fnv.update(&payload);
    if fnv.digest() != checksum {
        return Err(frame_err(format!(
            "checksum mismatch (header {checksum:#010x}, payload {:#010x})",
            fnv.digest()
        )));
    }
    let msg = decode_payload(ty, &payload);
    ChunkPool::global().recycle(payload);
    msg.map(Some)
}

/// `read_exact` that maps mid-frame EOF to a typed frame error.
fn read_payload_exact(r: &mut impl Read, dst: &mut [u8]) -> Result<()> {
    r.read_exact(dst).map_err(|e| match e.kind() {
        std::io::ErrorKind::UnexpectedEof => frame_err("truncated payload"),
        _ => Error::Io(e),
    })
}

/// Streaming decoder for buffer frames: the fixed metadata and each
/// chunk are read (and checksummed) in place, with chunk bytes landing
/// directly in [`ChunkPool`] storage.
fn take_part(
    r: &mut impl Read,
    dst: &mut [u8],
    fnv: &mut Fnv,
    remaining: &mut usize,
) -> Result<()> {
    if dst.len() > *remaining {
        return Err(frame_err("buffer payload shorter than its contents"));
    }
    read_payload_exact(r, dst)?;
    fnv.update(dst);
    *remaining -= dst.len();
    Ok(())
}

fn read_buffer_payload(r: &mut impl Read, length: u32, checksum: u32) -> Result<Msg> {
    let mut remaining = length as usize;
    let mut fnv = Fnv::new();
    let mut meta = [0u8; 25];
    take_part(r, &mut meta, &mut fnv, &mut remaining)?;
    let mut d = Dec::new(&meta);
    let pts_ns = d.u64()?;
    let duration_ns = d.u64()?;
    let seq = d.u64()?;
    let n = d.u8()? as usize;
    if n > MAX_TENSORS {
        return Err(frame_err(format!(
            "buffer with {n} chunks exceeds MAX_TENSORS {MAX_TENSORS}"
        )));
    }
    let mut chunks = Vec::with_capacity(n);
    for _ in 0..n {
        let mut len_bytes = [0u8; 4];
        take_part(r, &mut len_bytes, &mut fnv, &mut remaining)?;
        let clen = u32::from_le_bytes(len_bytes) as usize;
        if clen > remaining {
            return Err(frame_err("chunk length overruns buffer payload"));
        }
        let mut storage = ChunkPool::global().take(clen);
        take_part(r, &mut storage, &mut fnv, &mut remaining)?;
        chunks.push(Chunk::from_pooled(storage));
    }
    if remaining != 0 {
        return Err(frame_err(format!(
            "{remaining} trailing bytes after buffer payload"
        )));
    }
    if fnv.digest() != checksum {
        return Err(frame_err(format!(
            "checksum mismatch (header {checksum:#010x}, payload {:#010x})",
            fnv.digest()
        )));
    }
    let mut buf = Buffer::new(pts_ns, chunks);
    buf.duration_ns = duration_ns;
    buf.seq = seq;
    Ok(Msg::Buffer(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let bytes = encode(&msg).expect("encode");
        assert_eq!(decode(&bytes).expect("decode"), msg);
        // the streaming writer must produce the identical frame
        let mut streamed = Vec::new();
        write_msg(&mut streamed, &msg).expect("write_msg");
        assert_eq!(streamed, bytes);
    }

    #[test]
    fn control_messages_roundtrip() {
        roundtrip(Msg::Hello {
            topic: "ns/frames".into(),
            capacity: 64,
            credits: 61,
            qos: Qos::LatestOnly,
        });
        roundtrip(Msg::Eos);
        roundtrip(Msg::Detach);
        roundtrip(Msg::Credit(17));
        roundtrip(Msg::Fault(Fault {
            element: "tensor_filter0".into(),
            message: "index out of bounds".into(),
            panicked: true,
        }));
        roundtrip(Msg::RegPut {
            topic: "mtcnn/boxes".into(),
            addr: "127.0.0.1:41234".into(),
        });
        roundtrip(Msg::RegGet {
            topic: "mtcnn/boxes".into(),
        });
        roundtrip(Msg::RegAddr {
            addr: Some("127.0.0.1:41234".into()),
        });
        roundtrip(Msg::RegAddr { addr: None });
    }

    #[test]
    fn caps_roundtrip_including_display_lossy_variants() {
        // Caps::Any and audio sample counts do not survive the text
        // Display/parse pair — the binary codec must carry them.
        roundtrip(Msg::Caps(Caps::Any));
        roundtrip(Msg::Caps(Caps::Text));
        roundtrip(Msg::Caps(Caps::FlatBuf));
        roundtrip(Msg::Caps(Caps::Video(VideoInfo {
            format: VideoFormat::Nv12,
            width: 640,
            height: 480,
            fps_millis: 30_000,
        })));
        roundtrip(Msg::Caps(Caps::Audio(AudioInfo {
            rate: 16_000,
            channels: 2,
            samples_per_buffer: 1600,
        })));
        roundtrip(Msg::Caps(Caps::Tensor {
            info: TensorInfo::new(DType::F32, Dims::new(&[3, 64, 64])),
            fps_millis: 2_400_000,
        }));
        roundtrip(Msg::Caps(Caps::Tensors {
            infos: vec![
                TensorInfo::new(DType::U8, Dims::new(&[3, 224, 224, 1])),
                TensorInfo::new(DType::I64, Dims::new(&[1])),
            ],
            fps_millis: 0,
        }));
    }

    #[test]
    fn buffers_roundtrip_with_metadata_and_chunks() {
        let mut buf = Buffer::new(
            123_456_789,
            vec![
                Chunk::from_vec(vec![1, 2, 3, 4, 5]),
                Chunk::from_vec(Vec::new()),
                Chunk::from_vec((0..=255).collect()),
            ],
        );
        buf.duration_ns = 33_333_333;
        buf.seq = 42;
        let bytes = encode(&Msg::Buffer(buf.clone())).unwrap();
        let decoded = match decode(&bytes).unwrap() {
            Msg::Buffer(b) => b,
            other => panic!("expected buffer, got {other:?}"),
        };
        assert_eq!(decoded.pts_ns, buf.pts_ns);
        assert_eq!(decoded.duration_ns, buf.duration_ns);
        assert_eq!(decoded.seq, buf.seq);
        assert_eq!(decoded.chunks.len(), buf.chunks.len());
        for (a, b) in decoded.chunks.iter().zip(&buf.chunks) {
            assert_eq!(a.as_bytes_unaccounted(), b.as_bytes_unaccounted());
        }
    }

    #[test]
    fn corrupted_frames_yield_typed_errors() {
        let good = encode(&Msg::Credit(5)).unwrap();

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));

        // unsupported version
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));

        // unknown frame type (header checksum still valid)
        let mut bad = good.clone();
        bad[5] = 200;
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));

        // nonzero reserved flags
        let mut bad = good.clone();
        bad[6] = 1;
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));

        // flipped payload bit -> checksum mismatch
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));

        // truncation at every prefix length never panics
        for cut in 0..good.len() {
            match decode(&good[..cut]) {
                Err(Error::Frame(_)) => {}
                Ok(_) => panic!("decoded a truncated frame (cut {cut})"),
                Err(e) => panic!("wrong error for cut {cut}: {e}"),
            }
        }

        // absurd advertised length is corruption, not an allocation
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(decode(&bad), Err(Error::Frame(_))));
    }

    #[test]
    fn inconsistent_payloads_yield_typed_errors() {
        // a Hello whose inner string length overruns the payload
        let mut payload = Vec::new();
        put_u16(&mut payload, 1000); // claims 1000 bytes, none follow
        let mut fnv = Fnv::new();
        fnv.update(&payload);
        let mut frame = Vec::new();
        frame.extend_from_slice(&header(T_HELLO, payload.len() as u32, fnv.digest()));
        frame.extend_from_slice(&payload);
        assert!(matches!(decode(&frame), Err(Error::Frame(_))));

        // trailing garbage after a complete Eos payload
        let mut payload = vec![0u8; 3];
        payload[0] = 7;
        let mut fnv = Fnv::new();
        fnv.update(&payload);
        let mut frame = Vec::new();
        frame.extend_from_slice(&header(T_EOS, payload.len() as u32, fnv.digest()));
        frame.extend_from_slice(&payload);
        assert!(matches!(decode(&frame), Err(Error::Frame(_))));

        // invalid UTF-8 in a string field
        let mut payload = Vec::new();
        put_u16(&mut payload, 2);
        payload.extend_from_slice(&[0xff, 0xfe]);
        let mut fnv = Fnv::new();
        fnv.update(&payload);
        let mut frame = Vec::new();
        frame.extend_from_slice(&header(T_REG_GET, payload.len() as u32, fnv.digest()));
        frame.extend_from_slice(&payload);
        assert!(matches!(decode(&frame), Err(Error::Frame(_))));
    }

    #[test]
    fn boundary_eof_is_none_not_error() {
        let mut empty: &[u8] = &[];
        assert!(read_msg(&mut empty).unwrap().is_none());
        // two back-to-back frames then boundary EOF
        let mut stream = encode(&Msg::Eos).unwrap();
        stream.extend_from_slice(&encode(&Msg::Credit(1)).unwrap());
        let mut cursor: &[u8] = &stream;
        assert_eq!(read_msg(&mut cursor).unwrap(), Some(Msg::Eos));
        assert_eq!(read_msg(&mut cursor).unwrap(), Some(Msg::Credit(1)));
        assert!(read_msg(&mut cursor).unwrap().is_none());
    }
}
