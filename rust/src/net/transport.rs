//! `TcpTransport`: the [`Transport`] trait over TCP sockets with
//! per-subscriber credit-based flow control.
//!
//! ## Serve side (publisher process)
//!
//! A publisher port attaches to a topic in the transport's **private**
//! [`StreamRegistry`] — not the global one, so a loopback process that
//! both serves and subscribes never short-circuits the wire. The
//! transport lazily binds one data-plane listener; each accepted
//! connection handshakes with a `Hello` frame naming the topic, then
//! gets its own subscriber queue (`TopicInner::subscribe`) plus a
//! writer thread that sends one `Buffer` frame per **credit** and a
//! reader thread that banks incoming `Credit` grants. A full remote
//! queue therefore parks the publisher exactly like an in-pipeline
//! link (Blocking) or sheds with typed drops (Leaky/LatestOnly) —
//! the QoS machinery is the same `TopicInner` fan-out the in-process
//! transport uses.
//!
//! ## Subscriber side (consumer process)
//!
//! A subscriber port owns a **standalone** bounded [`Endpoint`] fed by
//! a background connector thread: resolve the topic in the
//! [`NetRegistry`](super::registry::NetRegistry), connect, `Hello`
//! with `capacity` and an initial credit grant of
//! `capacity - in_flight` (reconnects must not over-grant into a
//! queue that still holds undelivered frames), then loop reading
//! frames. Each element-side pop returns one `Credit`, so
//! `sent - credited <= capacity` bounds subscriber memory. A
//! connection that dies **without** `Eos`/`Fault` is retried
//! (re-resolving the registry, so a restarted publisher on a new port
//! is found); exhausted retries surface as a typed
//! [`StreamEnd::Fault`], never a clean EOS.
//!
//! Delivery is at-most-once across a reconnect: frames queued on the
//! dead connection's server-side endpoint are accounted as `closed`
//! drops, keeping `pushed == delivered + dropped + in_flight` exact on
//! both sides of the wire.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::thread::{self, JoinHandle};
use crate::sync::{Condvar, Mutex};

use crate::error::{Error, Fault, Result};
use crate::metrics::stats::{
    merge_latency, summarize_latency, TopicDrops, TopicSnapshot, LATENCY_BUCKETS,
};
use crate::net::registry::RegistryClient;
use crate::net::wire::{read_msg, write_msg, Msg};
use crate::pipeline::executor::{lock, SharedWaker};
use crate::pipeline::stream::{
    topic_publisher_port, Endpoint, EpPop, EpPush, PortRecv, PublisherPort, StreamRegistry,
    SubscriberPort, TopicInner, Transport,
};
use crate::pipeline::{Qos, StreamEnd};
use crate::tensor::Caps;

/// Configuration of one [`TcpTransport`] instance.
#[derive(Debug, Clone)]
pub struct TcpConfig {
    /// Address of the [`NetRegistry`](super::registry::NetRegistry)
    /// used for topic discovery (`"host:port"`).
    pub registry: String,
    /// Data-plane bind address for served topics. `"127.0.0.1:0"`
    /// (default) binds an ephemeral loopback port.
    pub bind: String,
    /// Host name published to the registry; defaults to the bound
    /// listener's IP (override when peers reach this process through
    /// a different interface/NAT name).
    pub advertise_host: Option<String>,
    /// Total budget for a subscriber's *initial* resolve + connect
    /// (publishers may register after subscribers start).
    pub connect_timeout: Duration,
    /// Reconnect attempts after a connection died mid-stream without
    /// `Eos`/`Fault`; exhausting them fails the subscription.
    pub reconnect_attempts: u32,
    /// Pause between resolution/reconnect attempts.
    pub reconnect_backoff: Duration,
}

impl TcpConfig {
    pub fn new(registry: impl Into<String>) -> TcpConfig {
        TcpConfig {
            registry: registry.into(),
            bind: "127.0.0.1:0".into(),
            advertise_host: None,
            connect_timeout: Duration::from_secs(10),
            reconnect_attempts: 8,
            reconnect_backoff: Duration::from_millis(100),
        }
    }
}

/// Live-connection tally on the serve side; [`TcpTransport::quiesce`]
/// waits for it to drain so a publisher process can exit knowing every
/// final `Eos`/`Fault` frame reached the socket.
#[derive(Default)]
struct ConnTracker {
    n: Mutex<usize>,
    cv: Condvar,
}

impl ConnTracker {
    fn inc(&self) {
        *lock(&self.n) += 1;
    }

    fn dec(&self) {
        let mut g = lock(&self.n);
        *g = g.saturating_sub(1);
        drop(g);
        self.cv.notify_all();
    }

    fn wait_zero(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = lock(&self.n);
        while *g > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (g2, _) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
        true
    }
}

/// State shared between the transport handle and its serve-side threads.
struct ServeShared {
    /// Private topic registry: served topics live here, isolated from
    /// the process-global in-proc registry.
    topics: StreamRegistry,
    conns: ConnTracker,
    stopped: AtomicBool,
    /// Accepted sockets, severed on transport drop so their threads exit.
    peers: Mutex<Vec<TcpStream>>,
}

/// Per-connection credit window on the serve side: the wire-protocol
/// invariant `sent - credited <= capacity` lives here. The writer
/// consumes one credit per `Buffer` frame ([`take`](CreditWindow::take))
/// and the reader banks grants ([`grant`](CreditWindow::grant)); a grant
/// that would lift the balance over the subscriber's advertised capacity
/// is a protocol violation and is refused, so the caller severs the
/// connection instead of overrunning the remote queue.
///
/// Public (and free of socket types) so `tests/check.rs` can explore
/// every writer/reader interleaving of the accounting under the model
/// scheduler.
pub struct CreditWindow {
    credits: Mutex<u64>,
    cv: Condvar,
    closed: AtomicBool,
    /// The subscriber's advertised queue capacity: a credit balance
    /// above this is a protocol violation and severs the connection.
    cap: u64,
}

impl CreditWindow {
    /// A window with `initial` banked credits; callers validate
    /// `initial <= cap` at the handshake before constructing.
    pub fn new(cap: u64, initial: u64) -> CreditWindow {
        CreditWindow {
            credits: Mutex::new(initial.min(cap)),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            cap,
        }
    }

    /// End the window: blocked takers return `false`, grants no-op.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Currently banked credits.
    pub fn balance(&self) -> u64 {
        *lock(&self.credits)
    }

    /// Block until one credit is available (consuming it) or the
    /// connection closed. `false` = closed.
    pub fn take(&self) -> bool {
        let mut g = lock(&self.credits);
        loop {
            if self.closed.load(Ordering::Acquire) {
                return false;
            }
            if *g > 0 {
                *g -= 1;
                return true;
            }
            let (g2, _) = self
                .cv
                .wait_timeout(g, Duration::from_millis(100))
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
    }

    /// Bank `n` returned credits and wake the writer. `false` means the
    /// grant would exceed the advertised capacity — an over-window
    /// protocol violation; the balance is left untouched and the caller
    /// must sever the connection.
    pub fn grant(&self, n: u64) -> bool {
        let mut g = lock(&self.credits);
        let balance = g.saturating_add(n);
        if balance > self.cap {
            return false;
        }
        *g = balance;
        drop(g);
        self.cv.notify_all();
        true
    }
}

struct ListenerState {
    advertised: String,
    local: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

/// Final counters of subscriptions whose port has been released,
/// accumulated per topic. A `PipelineReport` is snapshotted after its
/// elements dropped their ports; without this fold the subscriber side
/// of the wire would vanish from the report and the conservation
/// identity could not be audited post-run.
#[derive(Default)]
struct RetiredSubs {
    by_topic: Mutex<HashMap<String, RetiredSub>>,
}

struct RetiredSub {
    pushed: u64,
    delivered: u64,
    drops: TopicDrops,
    in_flight: u64,
    eos: bool,
    hist: [u64; LATENCY_BUCKETS],
}

impl Default for RetiredSub {
    fn default() -> RetiredSub {
        RetiredSub {
            pushed: 0,
            delivered: 0,
            drops: TopicDrops::default(),
            in_flight: 0,
            eos: true,
            hist: [0; LATENCY_BUCKETS],
        }
    }
}

/// State shared between a subscriber port and its connector thread.
struct SubShared {
    topic: String,
    qos: Qos,
    ep: Arc<Endpoint>,
    caps: Mutex<Option<Caps>>,
    /// Write half of the live connection (credit grants, detach).
    writer: Mutex<Option<TcpStream>>,
    detached: AtomicBool,
    /// Pairs with `detach_cv`: reconnect backoff waits here instead of
    /// busy-polling `detached`, and `detach()` notifies to end the wait.
    detach_mu: Mutex<()>,
    detach_cv: Condvar,
    connected: AtomicBool,
    retired: Arc<RetiredSubs>,
}

impl SubShared {
    fn fail(&self, message: String) {
        self.ep.fail(&Fault {
            element: format!("tcp:{}", self.topic),
            message,
            panicked: false,
        });
    }
}

impl Drop for SubShared {
    // Runs once the port *and* the connector thread released their
    // handles, so a weak upgrade in `snapshot` can never double-count
    // a subscription that also folded itself here.
    fn drop(&mut self) {
        let (c, hist) = self.ep.counters_and_hist();
        let mut g = lock(&self.retired.by_topic);
        let r = g.entry(self.topic.clone()).or_default();
        r.pushed += c.pushed;
        r.delivered += c.delivered;
        r.drops.qos_leaky += c.dropped.qos_leaky;
        r.drops.qos_latest += c.dropped.qos_latest;
        r.drops.closed += c.dropped.closed;
        r.in_flight += c.in_flight;
        r.eos &= self.ep.close_reason().is_some();
        merge_latency(&mut r.hist, &hist);
    }
}

/// The TCP tensor-query transport. Register with
/// [`register_tcp`](super::register_tcp); elements select it with
/// `transport=tcp` and an unchanged topic API.
pub struct TcpTransport {
    cfg: TcpConfig,
    registry: RegistryClient,
    serve: Arc<ServeShared>,
    listener: Mutex<Option<ListenerState>>,
    subs: Mutex<Vec<Weak<SubShared>>>,
    retired: Arc<RetiredSubs>,
}

impl TcpTransport {
    pub fn new(cfg: TcpConfig) -> TcpTransport {
        TcpTransport {
            registry: RegistryClient::new(cfg.registry.clone()),
            cfg,
            serve: Arc::new(ServeShared {
                topics: StreamRegistry::new(),
                conns: ConnTracker::default(),
                stopped: AtomicBool::new(false),
                peers: Mutex::new(Vec::new()),
            }),
            listener: Mutex::new(None),
            subs: Mutex::new(Vec::new()),
            retired: Arc::new(RetiredSubs::default()),
        }
    }

    /// The configuration this transport was built with.
    pub fn config(&self) -> &TcpConfig {
        &self.cfg
    }

    /// Bind the data-plane listener on first use; returns the address
    /// advertised to the registry.
    fn ensure_listener(&self) -> Result<String> {
        let mut g = lock(&self.listener);
        if let Some(l) = g.as_ref() {
            return Ok(l.advertised.clone());
        }
        let listener = TcpListener::bind(&self.cfg.bind).map_err(|e| Error::Connect {
            topic: "<data-plane>".into(),
            addr: self.cfg.bind.clone(),
            reason: e.to_string(),
        })?;
        let local = listener.local_addr()?;
        let host = self
            .cfg
            .advertise_host
            .clone()
            .unwrap_or_else(|| local.ip().to_string());
        let advertised = format!("{host}:{}", local.port());
        let shared = Arc::clone(&self.serve);
        let accept = thread::Builder::new()
            .name("nns-tcp-accept".into())
            .spawn(move || accept_loop(listener, shared))
            .expect("spawn tcp accept thread");
        *g = Some(ListenerState {
            advertised: advertised.clone(),
            local,
            accept: Some(accept),
        });
        Ok(advertised)
    }

    /// Wait until every serve-side connection finished writing its
    /// final frame (`Eos`/`Fault`). A publisher process calls this
    /// before exiting so an abrupt process end is never mistaken for a
    /// clean stream end by remote subscribers. `false` = timed out.
    pub fn quiesce(&self, timeout: Duration) -> bool {
        self.serve.conns.wait_zero(timeout)
    }

    /// Counter snapshots of everything this transport carries:
    /// served topics as `tcp-pub:<topic>`, subscriptions as
    /// `tcp-sub:<topic>`. Both obey the conservation identity
    /// `pushed == delivered + dropped + in_flight` (serve side under
    /// the topic lock; subscriber side under its endpoint lock).
    pub fn snapshot(&self) -> Vec<TopicSnapshot> {
        #[derive(Default)]
        struct SubAgg {
            live: usize,
            connected: bool,
            c: RetiredSub,
        }
        fn slot<'a>(agg: &'a mut Vec<(String, SubAgg)>, topic: &str) -> &'a mut SubAgg {
            if let Some(i) = agg.iter().position(|(t, _)| t == topic) {
                return &mut agg[i].1;
            }
            agg.push((topic.to_string(), SubAgg::default()));
            let i = agg.len() - 1;
            &mut agg[i].1
        }
        let mut out = Vec::new();
        for mut s in self.serve.topics.snapshot() {
            s.name = format!("tcp-pub:{}", s.name);
            out.push(s);
        }
        // One `tcp-sub:` entry per topic, folding live subscriptions
        // with already-retired generations so the conservation identity
        // survives port drops and reconnects.
        let mut agg: Vec<(String, SubAgg)> = Vec::new();
        let mut subs = lock(&self.subs);
        subs.retain(|w| w.strong_count() > 0);
        for shared in subs.iter().filter_map(Weak::upgrade) {
            let (c, hist) = shared.ep.counters_and_hist();
            let s = slot(&mut agg, &shared.topic);
            s.live += 1;
            s.connected |= shared.connected.load(Ordering::Acquire);
            s.c.eos &= shared.ep.close_reason().is_some();
            s.c.pushed += c.pushed;
            s.c.delivered += c.delivered;
            s.c.drops.qos_leaky += c.dropped.qos_leaky;
            s.c.drops.qos_latest += c.dropped.qos_latest;
            s.c.drops.closed += c.dropped.closed;
            s.c.in_flight += c.in_flight;
            merge_latency(&mut s.c.hist, &hist);
        }
        drop(subs);
        for (topic, r) in lock(&self.retired.by_topic).iter() {
            let s = slot(&mut agg, topic);
            s.c.eos &= r.eos;
            s.c.pushed += r.pushed;
            s.c.delivered += r.delivered;
            s.c.drops.qos_leaky += r.drops.qos_leaky;
            s.c.drops.qos_latest += r.drops.qos_latest;
            s.c.drops.closed += r.drops.closed;
            s.c.in_flight += r.in_flight;
            merge_latency(&mut s.c.hist, &r.hist);
        }
        for (topic, s) in agg {
            let drops = s.c.drops;
            out.push(TopicSnapshot {
                name: format!("tcp-sub:{topic}"),
                publishers: usize::from(s.connected),
                subscribers: s.live,
                eos: s.c.eos,
                published: s.c.pushed,
                pushed: s.c.pushed,
                delivered: s.c.delivered,
                dropped: drops.total(),
                drops,
                in_flight: s.c.in_flight,
                latency: summarize_latency(&s.c.hist),
            });
        }
        out
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn advertise(&self, topic: &str, qos: Qos) -> Result<Box<dyn PublisherPort>> {
        let addr = self.ensure_listener()?;
        self.registry.put(topic, &addr)?;
        // The port itself is the same topic-backed port the in-process
        // transport uses — against this transport's private registry,
        // where remote connections materialize as subscriber queues.
        Ok(topic_publisher_port(self.serve.topics.topic(topic), qos))
    }

    fn attach(&self, topic: &str, capacity: usize, qos: Qos) -> Result<Box<dyn SubscriberPort>> {
        let shared = Arc::new(SubShared {
            topic: topic.to_string(),
            qos,
            ep: Endpoint::new(capacity.max(1), qos, None),
            caps: Mutex::new(None),
            writer: Mutex::new(None),
            detached: AtomicBool::new(false),
            detach_mu: Mutex::new(()),
            detach_cv: Condvar::new(),
            connected: AtomicBool::new(false),
            retired: Arc::clone(&self.retired),
        });
        lock(&self.subs).push(Arc::downgrade(&shared));
        let thread_shared = Arc::clone(&shared);
        let cfg = self.cfg.clone();
        thread::Builder::new()
            .name(format!("nns-tcp-sub-{topic}"))
            .spawn(move || run_client(thread_shared, cfg))
            .expect("spawn tcp subscriber thread");
        Ok(Box::new(TcpSubscriberPort { shared }))
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.serve.stopped.store(true, Ordering::Release);
        if let Some(mut l) = lock(&self.listener).take() {
            // pop the accept loop, sever live peers, join the acceptor
            let _ = TcpStream::connect(l.local);
            for p in lock(&self.serve.peers).drain(..) {
                let _ = p.shutdown(Shutdown::Both);
            }
            if let Some(h) = l.accept.take() {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Serve side
// ---------------------------------------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<ServeShared>) {
    for stream in listener.incoming() {
        let Ok(stream) = stream else { break };
        if shared.stopped.load(Ordering::Acquire) {
            break;
        }
        if let Ok(peer) = stream.try_clone() {
            lock(&shared.peers).push(peer);
        }
        let conn_shared = Arc::clone(&shared);
        let _ = thread::Builder::new()
            .name("nns-tcp-conn".into())
            .spawn(move || serve_conn(conn_shared, stream));
    }
}

/// One accepted data-plane connection: handshake, subscribe the topic,
/// run the credit-gated writer inline with a reader thread for grants.
fn serve_conn(shared: Arc<ServeShared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let hello = match read_msg(&mut stream) {
        Ok(Some(Msg::Hello {
            topic,
            capacity,
            credits,
            qos,
        })) => (topic, capacity, credits, qos),
        // anything else (including clean close) is a failed handshake
        _ => return,
    };
    let (topic_name, capacity, credits, qos) = hello;
    let cap = capacity.max(1) as u64;
    if u64::from(credits) > cap {
        // typed for the logs we don't have: sever the handshake instead
        // of honoring an over-window grant (Error::Credit territory)
        return;
    }
    let Ok(reader_stream) = stream.try_clone() else {
        return;
    };
    let topic = shared.topics.topic(&topic_name);
    let ep = topic.subscribe(Some(cap as usize), qos);
    let conn = Arc::new(CreditWindow::new(cap, u64::from(credits)));
    shared.conns.inc();
    let reader_conn = Arc::clone(&conn);
    let reader_topic = Arc::clone(&topic);
    let reader_ep = Arc::clone(&ep);
    let reader = thread::Builder::new()
        .name("nns-tcp-credits".into())
        .spawn(move || server_reader(reader_conn, reader_topic, reader_ep, reader_stream))
        .ok();
    server_writer(&conn, &topic, &ep, stream);
    shared.conns.dec();
    if let Some(h) = reader {
        let _ = h.join();
    }
}

/// Credit-gated sender: one `Buffer` frame per credit, `Caps` as soon
/// as known, and a terminal `Eos`/`Fault` chosen by the endpoint's
/// close-reason (a `Closed` reason means the subscriber detached — no
/// terminal frame owed).
fn server_writer(
    conn: &CreditWindow,
    topic: &Arc<TopicInner>,
    ep: &Arc<Endpoint>,
    stream: TcpStream,
) {
    let shutdown_handle = stream.try_clone().ok();
    let mut w = std::io::BufWriter::new(stream);
    let mut caps_sent = false;
    let send_caps = |w: &mut std::io::BufWriter<TcpStream>, caps_sent: &mut bool| -> bool {
        if !*caps_sent {
            if let Some(c) = topic.caps() {
                if write_msg(w, &Msg::Caps(c)).is_err() {
                    return false;
                }
                *caps_sent = true;
            }
        }
        true
    };
    loop {
        match ep.pop_blocking() {
            Some(buf) => {
                if !conn.take() {
                    break;
                }
                if !send_caps(&mut w, &mut caps_sent)
                    || write_msg(&mut w, &Msg::Buffer(buf)).is_err()
                    || w.flush().is_err()
                {
                    break;
                }
            }
            None => {
                let _ = send_caps(&mut w, &mut caps_sent);
                match ep.close_reason() {
                    Some(StreamEnd::Fault(f)) => {
                        let _ = write_msg(&mut w, &Msg::Fault(f));
                    }
                    Some(StreamEnd::Closed) => {}
                    _ => {
                        let _ = write_msg(&mut w, &Msg::Eos);
                    }
                }
                let _ = w.flush();
                break;
            }
        }
    }
    topic.unsubscribe(ep);
    conn.close();
    if let Some(s) = shutdown_handle {
        let _ = s.shutdown(Shutdown::Both);
    }
}

/// Banks `Credit` grants; a `Detach`, a close, or any protocol breach
/// unsubscribes the queue so a dead subscriber never wedges the
/// publisher.
fn server_reader(
    conn: Arc<CreditWindow>,
    topic: Arc<TopicInner>,
    ep: Arc<Endpoint>,
    mut stream: TcpStream,
) {
    loop {
        match read_msg(&mut stream) {
            Ok(Some(Msg::Credit(n))) => {
                if !conn.grant(u64::from(n)) {
                    // over-window grant: protocol violation, sever
                    break;
                }
            }
            // Detach, clean close, corrupt frame, unexpected type: the
            // subscriber is gone (or broken) either way
            _ => break,
        }
    }
    topic.unsubscribe(&ep);
    conn.close();
    let _ = stream.shutdown(Shutdown::Both);
}

// ---------------------------------------------------------------------
// Subscriber side
// ---------------------------------------------------------------------

fn try_connect(reg: &RegistryClient, topic: &str) -> Option<TcpStream> {
    let addr = reg.get(topic).ok().flatten()?;
    let s = TcpStream::connect(addr).ok()?;
    let _ = s.set_nodelay(true);
    Some(s)
}

/// Sleep up to `total`, returning promptly when `detach()` fires. A
/// condvar wait (not a slice-and-poll loop): backoff burns no CPU and
/// detach latency is bounded by the notify, not a poll interval.
fn sleep_detachable(shared: &SubShared, total: Duration) {
    let deadline = Instant::now() + total;
    let mut g = lock(&shared.detach_mu);
    loop {
        if shared.detached.load(Ordering::Acquire) {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        // Timed-out or spurious wakes just re-check the flag/deadline.
        let (ng, _) = shared
            .detach_cv
            .wait_timeout(g, deadline - now)
            .unwrap_or_else(|e| e.into_inner());
        g = ng;
    }
}

/// Connector/reader thread of one subscription: resolve → connect →
/// handshake → read loop, with registry-re-resolving reconnects.
fn run_client(shared: Arc<SubShared>, cfg: TcpConfig) {
    let reg = RegistryClient::new(cfg.registry.clone());
    let initial_deadline = Instant::now() + cfg.connect_timeout;
    let mut connected_once = false;
    let mut attempts_left = cfg.reconnect_attempts;
    loop {
        if shared.detached.load(Ordering::Acquire) {
            return;
        }
        let Some(mut stream) = try_connect(&reg, &shared.topic) else {
            if !connected_once {
                if Instant::now() >= initial_deadline {
                    shared.fail(
                        Error::Connect {
                            topic: shared.topic.clone(),
                            addr: cfg.registry.clone(),
                            reason: format!(
                                "topic did not resolve within {:?}",
                                cfg.connect_timeout
                            ),
                        }
                        .to_string(),
                    );
                    return;
                }
            } else if attempts_left == 0 {
                shared.fail(
                    Error::Connect {
                        topic: shared.topic.clone(),
                        addr: cfg.registry.clone(),
                        reason: format!(
                            "connection lost; {} reconnect attempts exhausted",
                            cfg.reconnect_attempts
                        ),
                    }
                    .to_string(),
                );
                return;
            } else {
                attempts_left -= 1;
            }
            sleep_detachable(&shared, cfg.reconnect_backoff);
            continue;
        };
        // Handshake: advertise capacity, grant what the queue can take
        // right now (reconnects must not over-grant into a queue still
        // holding frames from the previous connection generation).
        let in_flight = shared.ep.counters_and_hist().0.in_flight;
        let credits = (shared.ep.capacity() as u64).saturating_sub(in_flight) as u32;
        let hello = Msg::Hello {
            topic: shared.topic.clone(),
            capacity: shared.ep.capacity() as u32,
            credits,
            qos: shared.qos,
        };
        if write_msg(&mut stream, &hello).is_err() || stream.flush().is_err() {
            sleep_detachable(&shared, cfg.reconnect_backoff);
            continue;
        }
        match stream.try_clone() {
            Ok(w) => *lock(&shared.writer) = Some(w),
            Err(_) => continue,
        }
        connected_once = true;
        attempts_left = cfg.reconnect_attempts;
        shared.connected.store(true, Ordering::Release);
        let outcome = client_read_loop(&shared, &mut stream);
        shared.connected.store(false, Ordering::Release);
        *lock(&shared.writer) = None;
        match outcome {
            ReadOutcome::Terminal => return,
            ReadOutcome::Lost => sleep_detachable(&shared, cfg.reconnect_backoff),
        }
    }
}

enum ReadOutcome {
    /// The stream ended definitively (Eos, Fault, detach, violation).
    Terminal,
    /// The connection died without a terminal frame — reconnect.
    Lost,
}

fn client_read_loop(shared: &SubShared, stream: &mut TcpStream) -> ReadOutcome {
    loop {
        match read_msg(stream) {
            Ok(Some(Msg::Caps(c))) => {
                *lock(&shared.caps) = Some(c);
            }
            Ok(Some(Msg::Buffer(buf))) => match shared.ep.try_push(buf) {
                EpPush::Ok => {}
                EpPush::Full(_) => {
                    // more frames than credits granted: protocol breach
                    shared.fail(
                        Error::Credit {
                            topic: shared.topic.clone(),
                            reason: "publisher sent a frame with no credit outstanding".into(),
                        }
                        .to_string(),
                    );
                    return ReadOutcome::Terminal;
                }
                // consumer closed/ended locally: nothing more to deliver
                EpPush::Closed(_) => return ReadOutcome::Terminal,
            },
            Ok(Some(Msg::Eos)) => {
                shared.ep.set_eos();
                return ReadOutcome::Terminal;
            }
            Ok(Some(Msg::Fault(f))) => {
                shared.ep.fail(&f);
                return ReadOutcome::Terminal;
            }
            Ok(Some(_)) => {
                shared.fail("unexpected frame type on subscriber connection".into());
                return ReadOutcome::Terminal;
            }
            Ok(None) | Err(_) => {
                if shared.detached.load(Ordering::Acquire) {
                    return ReadOutcome::Terminal;
                }
                return ReadOutcome::Lost;
            }
        }
    }
}

struct TcpSubscriberPort {
    shared: Arc<SubShared>,
}

impl TcpSubscriberPort {
    /// Return one credit for a popped frame (best-effort: a dead
    /// connection re-syncs credits in its reconnect `Hello`).
    fn grant_credit(&self) {
        let mut g = lock(&self.shared.writer);
        if let Some(w) = g.as_mut() {
            if write_msg(w, &Msg::Credit(1)).is_err() || w.flush().is_err() {
                *g = None;
            }
        }
    }
}

impl SubscriberPort for TcpSubscriberPort {
    fn topic_caps(&self) -> Option<Caps> {
        lock(&self.shared.caps).clone()
    }

    fn try_recv(&mut self) -> PortRecv {
        match self.shared.ep.try_pop() {
            EpPop::Item(b) => {
                self.grant_credit();
                PortRecv::Item(b)
            }
            EpPop::Empty => PortRecv::Empty,
            EpPop::End => PortRecv::End,
        }
    }

    fn add_waker(&mut self, w: &Arc<SharedWaker>) {
        self.shared.ep.add_consumer_waker(w);
    }

    fn detach(&mut self) {
        if !self.shared.detached.swap(true, Ordering::AcqRel) {
            if let Some(mut w) = lock(&self.shared.writer).take() {
                let _ = write_msg(&mut w, &Msg::Detach);
                let _ = w.flush();
                // the connector thread's blocking read shares this
                // socket: shutting it down unblocks the thread
                let _ = w.shutdown(Shutdown::Both);
            }
            self.shared.ep.close();
            // Pop the connector thread out of any reconnect backoff.
            let _g = lock(&self.shared.detach_mu);
            self.shared.detach_cv.notify_all();
        }
    }

    fn close_reason(&self) -> Option<StreamEnd> {
        self.shared.ep.close_reason()
    }
}

impl Drop for TcpSubscriberPort {
    fn drop(&mut self) {
        self.detach();
    }
}
