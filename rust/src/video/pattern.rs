//! Procedural test-frame generation (the `videotestsrc` substrate).
//!
//! Deterministic per (pattern, frame index): every run of every benchmark
//! sees identical pixel data, which keeps paper-table regeneration stable.

use crate::error::{Error, Result};
use crate::tensor::VideoFormat;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// SMPTE-ish vertical color bars that scroll horizontally per frame.
    Smpte,
    /// Diagonal gradient animated per frame.
    Gradient,
    /// Pseudo-random noise (deterministic per frame index).
    Snow,
    /// Moving white ball on black — gives detectors something localized.
    Ball,
}

impl Pattern {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "smpte" => Pattern::Smpte,
            "gradient" => Pattern::Gradient,
            "snow" => Pattern::Snow,
            "ball" => Pattern::Ball,
            other => return Err(Error::Parse(format!("unknown pattern {other:?}"))),
        })
    }
}

const BAR_COLORS: [[u8; 3]; 7] = [
    [191, 191, 191],
    [191, 191, 0],
    [0, 191, 191],
    [0, 191, 0],
    [191, 0, 191],
    [191, 0, 0],
    [0, 0, 191],
];

/// SplitMix64 — deterministic noise without external crates.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Generate one RGB frame of `pattern` at frame index `n`.
pub fn generate_rgb(pattern: Pattern, width: usize, height: usize, n: u64) -> Vec<u8> {
    let mut out = vec![0u8; width * height * 3];
    generate_rgb_into(pattern, width, height, n, &mut out);
    out
}

/// Generate one RGB frame into `out` (`width * height * 3` bytes; every
/// byte is overwritten). The `videotestsrc` element feeds this pooled
/// storage so steady-state frame production allocates nothing.
pub fn generate_rgb_into(
    pattern: Pattern,
    width: usize,
    height: usize,
    n: u64,
    out: &mut [u8],
) {
    debug_assert_eq!(out.len(), width * height * 3);
    match pattern {
        Pattern::Smpte => {
            let shift = (n as usize * 4) % width.max(1);
            for y in 0..height {
                for x in 0..width {
                    let xx = (x + shift) % width;
                    let bar = xx * BAR_COLORS.len() / width.max(1);
                    let c = BAR_COLORS[bar.min(BAR_COLORS.len() - 1)];
                    let o = (y * width + x) * 3;
                    out[o..o + 3].copy_from_slice(&c);
                }
            }
        }
        Pattern::Gradient => {
            for y in 0..height {
                for x in 0..width {
                    let o = (y * width + x) * 3;
                    out[o] = ((x * 255 / width.max(1)) as u64 + n) as u8;
                    out[o + 1] = ((y * 255 / height.max(1)) as u64 + n / 2) as u8;
                    out[o + 2] = (n % 256) as u8;
                }
            }
        }
        Pattern::Snow => {
            // one RNG draw per 8 bytes
            let words = (out.len() + 7) / 8;
            for w in 0..words {
                let v = splitmix64(n.wrapping_mul(0x5851_f42d).wrapping_add(w as u64));
                let bytes = v.to_le_bytes();
                let start = w * 8;
                let end = (start + 8).min(out.len());
                out[start..end].copy_from_slice(&bytes[..end - start]);
            }
        }
        Pattern::Ball => {
            let t = n as f64 * 0.1;
            let cx = (width as f64 / 2.0) * (1.0 + 0.8 * t.sin());
            let cy = (height as f64 / 2.0) * (1.0 + 0.8 * (t * 0.7).cos());
            let r = (width.min(height) as f64 / 8.0).max(2.0);
            // §Perf: fill the background once, then draw the disc as
            // per-row spans (O(h) math + memset instead of O(w*h) f64)
            out.fill(16);
            let y_lo = ((cy - r).floor().max(0.0)) as usize;
            let y_hi = ((cy + r).ceil().min(height as f64)) as usize;
            for y in y_lo..y_hi {
                let dy = y as f64 + 0.5 - cy;
                let half = (r * r - dy * dy).max(0.0).sqrt();
                let x0 = ((cx - half).floor().max(0.0)) as usize;
                let x1 = ((cx + half).ceil().min(width as f64)) as usize;
                if x0 < x1 {
                    out[(y * width + x0) * 3..(y * width + x1) * 3].fill(255);
                }
            }
        }
    }
}

/// Generate a frame in the requested output format.
pub fn generate_pattern(
    pattern: Pattern,
    format: VideoFormat,
    width: usize,
    height: usize,
    n: u64,
) -> Vec<u8> {
    let rgb = generate_rgb(pattern, width, height, n);
    match format {
        VideoFormat::Rgb => rgb,
        _ => super::convert::convert_raw(VideoFormat::Rgb, format, width, height, &rgb),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate_rgb(Pattern::Snow, 16, 16, 7);
        let b = generate_rgb(Pattern::Snow, 16, 16, 7);
        assert_eq!(a, b);
        let c = generate_rgb(Pattern::Snow, 16, 16, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn frame_sizes() {
        for (fmt, sz) in [
            (VideoFormat::Rgb, 16 * 16 * 3),
            (VideoFormat::Gray8, 16 * 16),
            (VideoFormat::Nv12, 16 * 16 * 3 / 2),
        ] {
            let f = generate_pattern(Pattern::Gradient, fmt, 16, 16, 0);
            assert_eq!(f.len(), sz, "{fmt:?}");
        }
    }

    #[test]
    fn ball_moves() {
        let a = generate_rgb(Pattern::Ball, 32, 32, 0);
        let b = generate_rgb(Pattern::Ball, 32, 32, 20);
        assert_ne!(a, b);
    }
}
