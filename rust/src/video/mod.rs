//! Raw-video substrate: procedural frame generation, color conversion,
//! scaling, cropping — the "off-the-shelf media filters" NNStreamer reuses
//! from GStreamer (P4). These are the *optimized* implementations; the
//! MediaPipe-like baseline deliberately re-implements them naively (see
//! [`crate::baselines::mediapipe_like`]), reproducing E4's pre-processor
//! comparison.

pub mod convert;
pub mod pattern;
pub mod scale;

pub use convert::{convert_format, convert_into};
pub use pattern::{generate_pattern, Pattern};
pub use scale::{crop, crop_into, crop_rect, scale_bilinear, scale_bilinear_into};

use crate::tensor::VideoFormat;

/// A borrowed view over one raw video frame.
#[derive(Debug, Clone, Copy)]
pub struct FrameView<'a> {
    pub format: VideoFormat,
    pub width: usize,
    pub height: usize,
    pub data: &'a [u8],
}

impl<'a> FrameView<'a> {
    pub fn new(format: VideoFormat, width: usize, height: usize, data: &'a [u8]) -> Self {
        debug_assert_eq!(data.len(), format.frame_size(width, height));
        Self {
            format,
            width,
            height,
            data,
        }
    }
}
