//! Scaling and cropping (the `videoscale` / `videocrop` substrate).
//!
//! Like `convert.rs`, each kernel has a `Vec`-returning form and an
//! `_into` form that writes into caller-provided (typically
//! [`crate::tensor::ChunkPool`]-recycled) storage.

use crate::tensor::VideoFormat;

fn packed_channels(format: VideoFormat, op: &str) -> usize {
    match format {
        VideoFormat::Rgb | VideoFormat::Bgr => 3,
        VideoFormat::Gray8 => 1,
        VideoFormat::Nv12 => panic!("{op} NV12 via RGB"),
    }
}

/// Bilinear scaling for packed formats (RGB/BGR/GRAY8) into `out`
/// (`dst_w * dst_h * channels` bytes; must not alias `data`). NV12
/// callers convert to RGB first (as real pipelines do before inference).
pub fn scale_bilinear_into(
    format: VideoFormat,
    src_w: usize,
    src_h: usize,
    dst_w: usize,
    dst_h: usize,
    data: &[u8],
    out: &mut [u8],
) {
    let ch = packed_channels(format, "scale");
    debug_assert_eq!(out.len(), dst_w * dst_h * ch);
    if src_w == dst_w && src_h == dst_h {
        out.copy_from_slice(data);
        return;
    }
    let x_ratio = if dst_w > 1 {
        (src_w - 1) as f32 / (dst_w - 1) as f32
    } else {
        0.0
    };
    let y_ratio = if dst_h > 1 {
        (src_h - 1) as f32 / (dst_h - 1) as f32
    } else {
        0.0
    };
    // Precompute the horizontal sampling table once per frame (§Perf: the
    // per-pixel float math dominated the naive loop; hoisting it makes the
    // inner loop a 4-tap weighted sum over byte offsets).
    let xmap: Vec<(usize, usize, f32)> = (0..dst_w)
        .map(|dx| {
            let fx = dx as f32 * x_ratio;
            let x0 = fx as usize;
            let x1 = (x0 + 1).min(src_w - 1);
            (x0 * ch, x1 * ch, fx - x0 as f32)
        })
        .collect();
    for dy in 0..dst_h {
        let fy = dy as f32 * y_ratio;
        let y0 = fy as usize;
        let y1 = (y0 + 1).min(src_h - 1);
        let wy = fy - y0 as f32;
        let row0 = &data[y0 * src_w * ch..(y0 * src_w + src_w) * ch];
        let row1 = &data[y1 * src_w * ch..(y1 * src_w + src_w) * ch];
        let out_row = &mut out[dy * dst_w * ch..(dy + 1) * dst_w * ch];
        for (dx, &(o0, o1, wx)) in xmap.iter().enumerate() {
            for c in 0..ch {
                let p00 = row0[o0 + c] as f32;
                let p01 = row0[o1 + c] as f32;
                let p10 = row1[o0 + c] as f32;
                let p11 = row1[o1 + c] as f32;
                let top = p00 + (p01 - p00) * wx;
                let bot = p10 + (p11 - p10) * wx;
                out_row[dx * ch + c] = (top + (bot - top) * wy + 0.5) as u8;
            }
        }
    }
}

/// Bilinear scaling into a fresh vector.
pub fn scale_bilinear(
    format: VideoFormat,
    src_w: usize,
    src_h: usize,
    dst_w: usize,
    dst_h: usize,
    data: &[u8],
) -> Vec<u8> {
    let ch = packed_channels(format, "scale");
    let mut out = vec![0u8; dst_w * dst_h * ch];
    scale_bilinear_into(format, src_w, src_h, dst_w, dst_h, data, &mut out);
    out
}

/// Clamp a crop request to the source bounds; returns `(x, y, w, h)` of
/// the rectangle [`crop_into`] will actually extract.
pub fn crop_rect(
    src_w: usize,
    src_h: usize,
    x: usize,
    y: usize,
    w: usize,
    h: usize,
) -> (usize, usize, usize, usize) {
    let x = x.min(src_w.saturating_sub(1));
    let y = y.min(src_h.saturating_sub(1));
    let w = w.min(src_w - x);
    let h = h.min(src_h - y);
    (x, y, w, h)
}

/// Crop a packed-format frame to an already-clamped rectangle (from
/// [`crop_rect`]) into `out` (`w * h * channels` bytes).
pub fn crop_into(
    format: VideoFormat,
    src_w: usize,
    x: usize,
    y: usize,
    w: usize,
    h: usize,
    data: &[u8],
    out: &mut [u8],
) {
    let ch = packed_channels(format, "crop");
    debug_assert_eq!(out.len(), w * h * ch);
    for row in 0..h {
        let src_off = ((y + row) * src_w + x) * ch;
        let dst_off = row * w * ch;
        out[dst_off..dst_off + w * ch].copy_from_slice(&data[src_off..src_off + w * ch]);
    }
}

/// Crop a packed-format frame to a rectangle (clamped to bounds).
pub fn crop(
    format: VideoFormat,
    src_w: usize,
    src_h: usize,
    x: usize,
    y: usize,
    w: usize,
    h: usize,
    data: &[u8],
) -> Vec<u8> {
    let ch = packed_channels(format, "crop");
    let (x, y, w, h) = crop_rect(src_w, src_h, x, y, w, h);
    let mut out = vec![0u8; w * h * ch];
    crop_into(format, src_w, x, y, w, h, data, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_scale_is_copy() {
        let data = vec![1u8, 2, 3, 4, 5, 6];
        let out = scale_bilinear(VideoFormat::Rgb, 2, 1, 2, 1, &data);
        assert_eq!(out, data);
    }

    #[test]
    fn downscale_averages() {
        // 2x2 gray -> 1x1: corner-anchored bilinear picks top-left
        let data = vec![0u8, 100, 100, 200];
        let out = scale_bilinear(VideoFormat::Gray8, 2, 2, 1, 1, &data);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn upscale_preserves_range() {
        let data = vec![0u8, 255];
        let out = scale_bilinear(VideoFormat::Gray8, 2, 1, 5, 1, &data);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0], 0);
        assert_eq!(out[4], 255);
        assert!(out.windows(2).all(|w| w[0] <= w[1]), "monotone: {out:?}");
    }

    #[test]
    fn crop_extracts_rect() {
        // 3x3 gray frame 0..9
        let data: Vec<u8> = (0..9).collect();
        let out = crop(VideoFormat::Gray8, 3, 3, 1, 1, 2, 2, &data);
        assert_eq!(out, vec![4, 5, 7, 8]);
    }

    #[test]
    fn crop_clamps_to_bounds() {
        let data: Vec<u8> = (0..9).collect();
        let out = crop(VideoFormat::Gray8, 3, 3, 2, 2, 5, 5, &data);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn into_matches_vec_path() {
        use crate::tensor::ChunkPool;
        let pool = ChunkPool::new();
        let data = crate::video::pattern::generate_rgb(
            crate::video::Pattern::Gradient,
            12,
            10,
            1,
        );
        // downscale, upscale, identity
        for (dw, dh) in [(6, 5), (20, 16), (12, 10)] {
            let expect = scale_bilinear(VideoFormat::Rgb, 12, 10, dw, dh, &data);
            let mut pooled = pool.take(dw * dh * 3);
            scale_bilinear_into(VideoFormat::Rgb, 12, 10, dw, dh, &data, &mut pooled);
            assert_eq!(pooled, expect, "pooled scale {dw}x{dh} bit-identical");
            pool.recycle(pooled);
        }
        let expect = crop(VideoFormat::Rgb, 12, 10, 2, 3, 5, 4, &data);
        let (x, y, w, h) = crop_rect(12, 10, 2, 3, 5, 4);
        let mut pooled = pool.take(w * h * 3);
        crop_into(VideoFormat::Rgb, 12, x, y, w, h, &data, &mut pooled);
        assert_eq!(pooled, expect, "pooled crop bit-identical");
    }
}
