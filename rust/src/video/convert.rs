//! Color-space / pixel-format conversion (the `videoconvert` substrate).
//!
//! Row-oriented implementations with per-row inner loops the compiler can
//! vectorize — these stand in for the SIMD/hardware-accelerated media
//! filters that come "off the shelf" with GStreamer (the paper's P4 and
//! the E4 pre-processing comparison hinge on these being fast).
//!
//! Every conversion has two entry points: `convert_raw` returns a fresh
//! `Vec<u8>` (tests, one-off callers) and [`convert_into`] writes into a
//! caller-provided buffer — the `videoconvert` element feeds it storage
//! from the [`crate::tensor::ChunkPool`] so steady-state frames allocate
//! nothing. Size the destination with `VideoFormat::frame_size`.

use crate::tensor::VideoFormat;

/// Convert `data` between raw formats into `out` (sized
/// `to.frame_size(width, height)`; `data` and `out` must not alias).
/// Same-format input is copied — the `videoconvert` element
/// short-circuits that case by forwarding the input chunk untouched
/// instead of calling here.
pub fn convert_into(
    from: VideoFormat,
    to: VideoFormat,
    width: usize,
    height: usize,
    data: &[u8],
    out: &mut [u8],
) {
    use VideoFormat::*;
    debug_assert_eq!(out.len(), to.frame_size(width, height));
    match (from, to) {
        (a, b) if a == b => out.copy_from_slice(data),
        (Rgb, Bgr) | (Bgr, Rgb) => swap_rb_into(data, out),
        (Rgb, Gray8) => rgb_to_gray_into(data, false, out),
        (Bgr, Gray8) => rgb_to_gray_into(data, true, out),
        (Gray8, Rgb) | (Gray8, Bgr) => gray_to_rgb_into(data, out),
        (Rgb, Nv12) => rgb_to_nv12_into(data, width, height, false, out),
        (Bgr, Nv12) => rgb_to_nv12_into(data, width, height, true, out),
        (Nv12, Rgb) => nv12_to_rgb_into(data, width, height, false, out),
        (Nv12, Bgr) => nv12_to_rgb_into(data, width, height, true, out),
        (Nv12, Gray8) => out.copy_from_slice(&data[..width * height]),
        (Gray8, Nv12) => {
            out[..width * height].copy_from_slice(data);
            out[width * height..].fill(128);
        }
        // equal-format pairs are handled by the first arm; rustc cannot see
        // through the guard, so spell it out
        (Rgb, Rgb) | (Bgr, Bgr) | (Gray8, Gray8) | (Nv12, Nv12) => {
            out.copy_from_slice(data)
        }
    }
}

/// Convert `data` between raw formats into a fresh vector.
pub fn convert_raw(
    from: VideoFormat,
    to: VideoFormat,
    width: usize,
    height: usize,
    data: &[u8],
) -> Vec<u8> {
    let mut out = vec![0u8; to.frame_size(width, height)];
    convert_into(from, to, width, height, data, &mut out);
    out
}

/// Public entry used by non-element callers.
pub fn convert_format(
    from: VideoFormat,
    to: VideoFormat,
    width: usize,
    height: usize,
    data: &[u8],
) -> Vec<u8> {
    convert_raw(from, to, width, height, data)
}

fn swap_rb_into(data: &[u8], out: &mut [u8]) {
    for (src, dst) in data.chunks_exact(3).zip(out.chunks_exact_mut(3)) {
        dst[0] = src[2];
        dst[1] = src[1];
        dst[2] = src[0];
    }
}

fn rgb_to_gray_into(data: &[u8], bgr: bool, out: &mut [u8]) {
    let (ri, bi) = if bgr { (2, 0) } else { (0, 2) };
    for (px, dst) in data.chunks_exact(3).zip(out.iter_mut()) {
        // integer BT.601 luma
        let y = 77 * px[ri] as u32 + 150 * px[1] as u32 + 29 * px[bi] as u32;
        *dst = (y >> 8) as u8;
    }
}

fn gray_to_rgb_into(data: &[u8], out: &mut [u8]) {
    for (&g, dst) in data.iter().zip(out.chunks_exact_mut(3)) {
        dst[0] = g;
        dst[1] = g;
        dst[2] = g;
    }
}

fn rgb_to_nv12_into(data: &[u8], width: usize, height: usize, bgr: bool, out: &mut [u8]) {
    let (ri, bi) = if bgr { (2, 0) } else { (0, 2) };
    // luma plane
    for (i, px) in data.chunks_exact(3).enumerate() {
        let y = 77 * px[ri] as u32 + 150 * px[1] as u32 + 29 * px[bi] as u32;
        out[i] = (y >> 8) as u8;
    }
    // interleaved half-res chroma
    let uv_base = width * height;
    for cy in 0..height / 2 {
        for cx in 0..width / 2 {
            let o = (cy * 2 * width + cx * 2) * 3;
            let r = data[o + ri] as i32;
            let g = data[o + 1] as i32;
            let b = data[o + bi] as i32;
            let u = ((-43 * r - 84 * g + 127 * b) >> 8) + 128;
            let v = ((127 * r - 106 * g - 21 * b) >> 8) + 128;
            let uo = uv_base + cy * width + cx * 2;
            out[uo] = u.clamp(0, 255) as u8;
            out[uo + 1] = v.clamp(0, 255) as u8;
        }
    }
}

fn nv12_to_rgb_into(data: &[u8], width: usize, height: usize, bgr: bool, out: &mut [u8]) {
    let (ri, bi) = if bgr { (2, 0) } else { (0, 2) };
    let uv_base = width * height;
    for y in 0..height {
        for x in 0..width {
            let yy = data[y * width + x] as i32;
            let uo = uv_base + (y / 2) * width + (x / 2) * 2;
            let u = data[uo] as i32 - 128;
            let v = data[uo + 1] as i32 - 128;
            let r = yy + ((359 * v) >> 8);
            let g = yy - ((88 * u + 183 * v) >> 8);
            let b = yy + ((454 * u) >> 8);
            let o = (y * width + x) * 3;
            out[o + ri] = r.clamp(0, 255) as u8;
            out[o + 1] = g.clamp(0, 255) as u8;
            out[o + bi] = b.clamp(0, 255) as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use VideoFormat::*;

    #[test]
    fn rgb_bgr_roundtrip() {
        let rgb = vec![10, 20, 30, 40, 50, 60];
        let bgr = convert_raw(Rgb, Bgr, 2, 1, &rgb);
        assert_eq!(bgr, vec![30, 20, 10, 60, 50, 40]);
        assert_eq!(convert_raw(Bgr, Rgb, 2, 1, &bgr), rgb);
    }

    #[test]
    fn gray_of_white_is_white() {
        let rgb = vec![255u8; 4 * 3];
        let g = convert_raw(Rgb, Gray8, 2, 2, &rgb);
        assert!(g.iter().all(|&v| v >= 254), "{g:?}");
    }

    #[test]
    fn nv12_roundtrip_preserves_luma_shape() {
        // gradient frame: NV12 roundtrip should keep gross structure
        let rgb = crate::video::pattern::generate_rgb(
            crate::video::Pattern::Gradient,
            16,
            16,
            0,
        );
        let nv = convert_raw(Rgb, Nv12, 16, 16, &rgb);
        assert_eq!(nv.len(), 16 * 16 * 3 / 2);
        let back = convert_raw(Nv12, Rgb, 16, 16, &nv);
        assert_eq!(back.len(), rgb.len());
        // average error tolerably small (chroma subsampling loses detail)
        let err: f64 = rgb
            .iter()
            .zip(&back)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum::<f64>()
            / rgb.len() as f64;
        assert!(err < 40.0, "roundtrip err {err}");
    }

    #[test]
    fn into_matches_vec_path_for_all_format_pairs() {
        use crate::tensor::ChunkPool;
        let formats = [Rgb, Bgr, Gray8, Nv12];
        let (w, h) = (16, 16);
        let rgb = crate::video::pattern::generate_rgb(
            crate::video::Pattern::Gradient,
            w,
            h,
            3,
        );
        let pool = ChunkPool::new();
        for from in formats {
            let src = convert_raw(Rgb, from, w, h, &rgb);
            for to in formats {
                let expect = convert_raw(from, to, w, h, &src);
                let mut pooled = pool.take(to.frame_size(w, h));
                convert_into(from, to, w, h, &src, &mut pooled);
                assert_eq!(
                    pooled, expect,
                    "pooled {from:?}->{to:?} must be bit-identical"
                );
                pool.recycle(pooled);
            }
        }
    }
}
