//! `nnscheck` model-check front end (`--features check` only).
//!
//! [`explore`] runs a model closure under the controlled scheduler in
//! [`super::sched`] many times — first a budget of seeded random walks,
//! then a bounded-preemption DFS — and turns the first failing
//! execution into a replayable [`Counterexample`]. The workflow:
//!
//! ```text
//! explore(&Config::default(), model)      # CI: fixed seed budget
//!   -> Outcome::Fail(cex)                 # cex prints its seed/trace
//! replay(cex.seed.unwrap(), model)        # exact re-run under a
//!                                         # debugger / with prints
//! ```
//!
//! One seed determines one exact interleaving, so "attach the seed to
//! the bug report" is a complete reproduction recipe. The DFS phase
//! complements the random phase: with a preemption bound of k it
//! systematically enumerates every schedule that context-switches at
//! most k times at points where the running thread could have
//! continued — most real concurrency bugs need only 1–2 forced
//! preemptions (the bound is the classic CHESS observation), and the
//! enumeration is deterministic, so CI does not depend on random luck.
//!
//! Models must be **closed**: every thread they spawn is spawned through
//! [`crate::sync::thread`] and every blocking operation goes through the
//! shim types — a model thread blocking on an uninstrumented primitive
//! would stall the scheduler (the run would die on the decision budget).
//! Models also must be **deterministic modulo scheduling**: same
//! decisions ⇒ same behavior. Do not branch on wall-clock time or
//! process-global counters inside a model.

use std::fmt;
use std::panic::AssertUnwindSafe;
use std::sync::Mutex as StdMutex;

use once_cell::sync::Lazy;

use super::sched::{self, Decision, Failure, Mode, RunReport};

/// Exploration budget. `Default` reads `NNSCHECK_SEED` and
/// `NNSCHECK_ITERS` so CI can pin the budget without code changes.
#[derive(Debug, Clone)]
pub struct Config {
    /// Base seed for the random-walk phase; iteration `i` runs the
    /// derived seed `base + i` (each is independently replayable).
    pub seed: u64,
    /// Number of random-walk executions.
    pub iters: usize,
    /// Per-execution decision budget (livelock/runaway guard).
    pub max_decisions: usize,
    /// Preemption bound for the DFS phase; `None` skips the phase.
    pub preemption_bound: Option<usize>,
    /// Ceiling on DFS executions (the bounded tree can still be large).
    pub dfs_max_runs: usize,
}

impl Default for Config {
    fn default() -> Config {
        let seed = std::env::var("NNSCHECK_SEED")
            .ok()
            .and_then(|v| parse_u64(&v))
            .unwrap_or(0x5EED_0000_0001);
        let iters = std::env::var("NNSCHECK_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(256);
        Config {
            seed,
            iters,
            max_decisions: 50_000,
            preemption_bound: Some(2),
            dfs_max_runs: 2_000,
        }
    }
}

fn parse_u64(v: &str) -> Option<u64> {
    if let Some(hex) = v.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}

/// A failing execution, with everything needed to reproduce it.
#[derive(Debug, Clone)]
pub struct Counterexample {
    /// Seed of the failing random walk (None when found by DFS).
    pub seed: Option<u64>,
    /// Full decision trace of the failing execution — replayable via
    /// [`replay_trace`] regardless of how it was found.
    pub trace: Vec<u32>,
    pub failure: Failure,
}

impl fmt::Display for Counterexample {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "nnscheck counterexample: {}", self.failure.message)?;
        match self.seed {
            Some(s) => writeln!(
                f,
                "  replay: seed {s:#x} (NNSCHECK_SEED={s:#x}, or check::replay({s:#x}, model))"
            )?,
            None => writeln!(f, "  found by bounded-preemption DFS")?,
        }
        write!(f, "  trace ({} decisions): [", self.trace.len())?;
        for (i, d) in self.trace.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Result of an [`explore`] run.
#[derive(Debug)]
pub enum Outcome {
    /// Every explored execution satisfied the model's assertions.
    Pass {
        /// Executions explored (random + DFS).
        runs: usize,
    },
    Fail(Box<Counterexample>),
}

impl Outcome {
    pub fn is_pass(&self) -> bool {
        matches!(self, Outcome::Pass { .. })
    }

    pub fn counterexample(&self) -> Option<&Counterexample> {
        match self {
            Outcome::Pass { .. } => None,
            Outcome::Fail(cex) => Some(cex),
        }
    }
}

/// Model executions are process-global (the shim consults thread-locals
/// of real OS threads): serialize them so `cargo test`'s parallel test
/// threads cannot interleave two models.
static MODEL_GATE: Lazy<StdMutex<()>> = Lazy::new(|| StdMutex::new(()));

fn run_once<F: Fn()>(mode: Mode, max_decisions: usize, f: &F) -> RunReport {
    sched::run_model(mode, max_decisions, AssertUnwindSafe(|| f()))
}

fn picks(trace: &[Decision]) -> Vec<u32> {
    trace.iter().map(|d| d.picked).collect()
}

fn preemptions_before(trace: &[Decision], upto: usize) -> usize {
    trace[..upto]
        .iter()
        .filter(|d| d.current_was_runnable && d.picked != 0)
        .count()
}

/// Explore interleavings of `f` under the configured budget. Returns
/// the first failure as a replayable counterexample (also printed to
/// stderr so a failing CI log carries the seed).
pub fn explore<F: Fn()>(cfg: &Config, f: F) -> Outcome {
    let _gate = MODEL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let mut runs = 0usize;

    // Phase 1: seeded random walks.
    for i in 0..cfg.iters {
        let seed = cfg.seed.wrapping_add(i as u64);
        let report = run_once(Mode::Random(seed), cfg.max_decisions, &f);
        runs += 1;
        if let Some(failure) = report.failure {
            let cex = Counterexample {
                seed: Some(seed),
                trace: picks(&report.trace),
                failure,
            };
            eprintln!("{cex}");
            return Outcome::Fail(Box::new(cex));
        }
    }

    // Phase 2: bounded-preemption DFS. Prefixes force decisions; beyond
    // a prefix the scheduler keeps the current thread running (choice
    // 0), so the baseline is the preemption-free execution and each
    // backtrack introduces exactly one more forced switch.
    if let Some(bound) = cfg.preemption_bound {
        let mut prefix: Vec<u32> = Vec::new();
        for _ in 0..cfg.dfs_max_runs {
            let report = run_once(
                Mode::Replay(prefix.clone()),
                cfg.max_decisions,
                &f,
            );
            runs += 1;
            if let Some(failure) = report.failure {
                let cex = Counterexample {
                    seed: None,
                    trace: picks(&report.trace),
                    failure,
                };
                eprintln!("{cex}");
                return Outcome::Fail(Box::new(cex));
            }
            // Backtrack: deepest decision with an untried sibling that
            // stays within the preemption bound.
            let trace = report.trace;
            let mut next: Option<Vec<u32>> = None;
            let mut i = trace.len();
            while i > 0 {
                i -= 1;
                let d = trace[i];
                let base = preemptions_before(&trace, i);
                let mut c = d.picked + 1;
                while c < d.options {
                    let cost = usize::from(d.current_was_runnable && c != 0);
                    if base + cost <= bound {
                        let mut p = picks(&trace[..i]);
                        p.push(c);
                        next = Some(p);
                        break;
                    }
                    c += 1;
                }
                if next.is_some() {
                    break;
                }
            }
            match next {
                Some(p) => prefix = p,
                None => break, // bounded tree exhausted
            }
        }
    }

    Outcome::Pass { runs }
}

/// Re-run `f` under the exact interleaving of `seed`. Returns the
/// failure if it reproduces (assertion panics inside the model are
/// captured, not propagated — inspect the return value).
pub fn replay<F: Fn()>(seed: u64, f: F) -> Option<Failure> {
    let _gate = MODEL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    run_once(Mode::Random(seed), Config::default().max_decisions, &f).failure
}

/// Re-run `f` forcing a recorded decision trace (counterexamples from
/// the DFS phase, or traces shared from another machine).
pub fn replay_trace<F: Fn()>(trace: &[u32], f: F) -> Option<Failure> {
    let _gate = MODEL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    run_once(
        Mode::Replay(trace.to_vec()),
        Config::default().max_decisions,
        &f,
    )
    .failure
}
