//! Lock-order analysis (the `nnscheck` analysis layer, part 2 of 3).
//!
//! Every [`super::Mutex`] / [`super::RwLock`] construction site is a
//! lock *class* identified by a stable [`SiteId`] (`file:line:column`).
//! Each thread keeps a stack of the classes it currently holds; when it
//! acquires class `B` while the top of its stack is class `A`, the
//! directed edge `A -> B` enters a process-global order graph. A cycle
//! in that graph is a potential deadlock: two code paths that take the
//! same classes in opposite orders (the classic AB/BA inversion) — even
//! if this particular run never interleaved them fatally. The closing
//! edge is detected the moment it is inserted and reported with both
//! sites plus the path that completes the cycle.
//!
//! Design points, in the order they matter:
//!
//! * **Record, never panic.** A report is appended (and printed once to
//!   stderr) but execution continues — an analysis layer must not turn
//!   a latent hazard into a deterministic crash in the middle of the
//!   ordinary test suite. Tests assert on [`global_cycles`] /
//!   [`global_is_acyclic`] explicitly.
//! * **Classes, not instances.** Two *different* topic locks acquired
//!   in both orders by disjoint call paths still report: a discipline
//!   stated per class ("hub map before topic state") is what reviewers
//!   and the DESIGN.md contracts actually promise. Intentional
//!   same-class nesting would need a lock-level annotation; the crate
//!   has none today, so a self-edge also reports.
//! * **Condvar waits release.** A wait pops the guard's class for its
//!   duration (the lock really is released) and re-records it on wake.
//!   If other classes are still held across the wait, that is recorded
//!   as a [`WaitReport`] — waiting while holding an unrelated lock is
//!   the shape of every convoy/missed-wakeup bug, but it is legitimate
//!   in bounded-timeout form (the executor's `pop_timeout` under the
//!   step lock), so wait reports are diagnostics, not failures.
//! * **Debug builds only.** The callers in `super` compile these hooks
//!   under `cfg(debug_assertions)`; release binaries carry zero
//!   lockdep state. `NNS_LOCKDEP=0` disables at runtime.
//!
//! The AB/BA fixture test (`tests/lockdep.rs`) uses
//! [`with_isolated_graph`] so its deliberate inversion lands in a
//! thread-local graph instead of polluting the process-global one that
//! the clean-suite acyclicity assertion reads.

// Release builds compile the hooks but never call them (the shim gates
// its calls on `cfg(debug_assertions)`); silence the resulting
// dead-code analysis only there, so debug builds still flag real rot.
#![cfg_attr(not(debug_assertions), allow(dead_code))]

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::panic::Location;
use std::rc::Rc;
use std::sync::Mutex as StdMutex;

use once_cell::sync::Lazy;

/// Stable identity of a lock class: the `#[track_caller]` construction
/// site of the `Mutex`/`RwLock`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SiteId {
    pub file: &'static str,
    pub line: u32,
    pub column: u32,
}

impl SiteId {
    pub fn of(loc: &'static Location<'static>) -> SiteId {
        SiteId {
            file: loc.file(),
            line: loc.line(),
            column: loc.column(),
        }
    }
}

impl fmt::Display for SiteId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}", self.file, self.line, self.column)
    }
}

/// One detected lock-order cycle: inserting `from -> to` closed a loop.
/// `path` walks the pre-existing edges from `to` back to `from`, so the
/// full inversion reads `from -> to -> ... -> from`.
#[derive(Debug, Clone)]
pub struct CycleReport {
    pub from: SiteId,
    pub to: SiteId,
    pub path: Vec<SiteId>,
}

impl fmt::Display for CycleReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "lock-order cycle: {} -> {} closes [",
            self.from, self.to
        )?;
        for (i, s) in self.path.iter().enumerate() {
            if i > 0 {
                write!(f, " -> ")?;
            }
            write!(f, "{s}")?;
        }
        write!(f, " -> {}]", self.from)
    }
}

/// A condvar wait that happened while other lock classes were held.
#[derive(Debug, Clone)]
pub struct WaitReport {
    /// Class of the mutex the wait released.
    pub waited_at: SiteId,
    /// Classes still held across the wait (innermost last).
    pub held: Vec<SiteId>,
}

#[derive(Default)]
struct Graph {
    edges: HashMap<SiteId, HashSet<SiteId>>,
    cycles: Vec<CycleReport>,
    waits: Vec<WaitReport>,
    /// Dedup for wait reports: (waited_at, innermost held).
    wait_seen: HashSet<(SiteId, SiteId)>,
}

impl Graph {
    /// Insert `from -> to`; on first insertion, check whether the new
    /// edge closes a cycle and record a report if so.
    fn add_edge(&mut self, from: SiteId, to: SiteId) {
        if from == to {
            // Same-class nesting: two instances of one class held at
            // once. Report once per class.
            if self.edges.entry(from).or_default().insert(to) {
                let report = CycleReport {
                    from,
                    to,
                    path: vec![to],
                };
                eprintln!("nnscheck lockdep: {report}");
                self.cycles.push(report);
            }
            return;
        }
        if !self.edges.entry(from).or_default().insert(to) {
            return; // already known
        }
        if let Some(path) = self.find_path(to, from) {
            let report = CycleReport { from, to, path };
            eprintln!("nnscheck lockdep: {report}");
            self.cycles.push(report);
        }
    }

    /// DFS path from `start` to `goal` over recorded edges (excluding
    /// the just-inserted closing edge is unnecessary: a `to ->* from`
    /// path plus `from -> to` is the cycle we want to show).
    fn find_path(&self, start: SiteId, goal: SiteId) -> Option<Vec<SiteId>> {
        let mut stack = vec![start];
        let mut parent: HashMap<SiteId, SiteId> = HashMap::new();
        let mut seen: HashSet<SiteId> = HashSet::new();
        seen.insert(start);
        while let Some(node) = stack.pop() {
            if node == goal {
                // Reconstruct start -> ... -> goal, then drop the goal
                // (the caller appends `from` itself when printing).
                let mut path = vec![node];
                let mut cur = node;
                while let Some(&p) = parent.get(&cur) {
                    path.push(p);
                    cur = p;
                }
                path.reverse();
                path.pop();
                return Some(path);
            }
            if let Some(next) = self.edges.get(&node) {
                for &n in next {
                    if seen.insert(n) {
                        parent.insert(n, node);
                        stack.push(n);
                    }
                }
            }
        }
        None
    }

    fn record_wait(&mut self, waited_at: SiteId, held: &[SiteId]) {
        let innermost = match held.last() {
            Some(&s) => s,
            None => return,
        };
        if self.wait_seen.insert((waited_at, innermost)) {
            self.waits.push(WaitReport {
                waited_at,
                held: held.to_vec(),
            });
        }
    }
}

static GLOBAL: Lazy<StdMutex<Graph>> = Lazy::new(|| StdMutex::new(Graph::default()));

static ENABLED: Lazy<bool> =
    Lazy::new(|| std::env::var("NNS_LOCKDEP").map_or(true, |v| v != "0"));

thread_local! {
    /// Lock classes this thread currently holds, outermost first.
    static HELD: RefCell<Vec<SiteId>> = const { RefCell::new(Vec::new()) };
    /// Fixture override: edges from this thread go to an isolated graph.
    static ISOLATED: RefCell<Option<Rc<RefCell<Graph>>>> = const { RefCell::new(None) };
}

/// True when lock-order analysis is active (debug build, not disabled).
pub fn enabled() -> bool {
    *ENABLED
}

fn with_graph<R>(f: impl FnOnce(&mut Graph) -> R) -> R {
    let isolated = ISOLATED.with(|g| g.borrow().clone());
    match isolated {
        Some(g) => f(&mut g.borrow_mut()),
        None => f(&mut GLOBAL.lock().unwrap_or_else(|e| e.into_inner())),
    }
}

/// Hook: `site`'s class is being acquired by this thread.
pub(super) fn on_acquire(loc: &'static Location<'static>) {
    if !enabled() {
        return;
    }
    let site = SiteId::of(loc);
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(&top) = held.last() {
            with_graph(|g| g.add_edge(top, site));
        }
        held.push(site);
    });
}

/// Hook: a guard of `site`'s class was dropped by this thread.
pub(super) fn on_release(loc: &'static Location<'static>) {
    if !enabled() {
        return;
    }
    let site = SiteId::of(loc);
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        // Guards may drop out of LIFO order; remove the innermost match.
        if let Some(pos) = held.iter().rposition(|&s| s == site) {
            held.remove(pos);
        }
    });
}

/// Hook: a condvar wait is releasing `site`'s class for its duration.
/// Records a wait-while-holding diagnostic if other classes remain held.
pub(super) fn on_wait(loc: &'static Location<'static>) {
    if !enabled() {
        return;
    }
    let site = SiteId::of(loc);
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&s| s == site) {
            held.remove(pos);
        }
        if !held.is_empty() {
            let snapshot: Vec<SiteId> = held.clone();
            with_graph(|g| g.record_wait(site, &snapshot));
        }
    });
}

/// All lock-order cycles recorded in the process-global graph so far.
pub fn global_cycles() -> Vec<CycleReport> {
    GLOBAL
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .cycles
        .clone()
}

/// All wait-while-holding diagnostics recorded globally so far.
pub fn global_wait_reports() -> Vec<WaitReport> {
    GLOBAL
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .waits
        .clone()
}

/// True when the process-global order graph contains no cycle. Every
/// edge insertion checks for cycles eagerly, so this is equivalent to
/// `global_cycles().is_empty()`; recomputing keeps the assertion honest
/// against future incremental-check bugs.
pub fn global_is_acyclic() -> bool {
    let g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    if !g.cycles.is_empty() {
        return false;
    }
    // Kahn-style check over the recorded edges.
    let mut indeg: HashMap<SiteId, usize> = HashMap::new();
    for (from, tos) in &g.edges {
        indeg.entry(*from).or_insert(0);
        for to in tos {
            *indeg.entry(*to).or_insert(0) += 1;
        }
    }
    let mut queue: Vec<SiteId> = indeg
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&s, _)| s)
        .collect();
    let mut visited = 0usize;
    let total = indeg.len();
    while let Some(node) = queue.pop() {
        visited += 1;
        if let Some(next) = g.edges.get(&node) {
            for n in next {
                let d = indeg.get_mut(n).expect("edge target in indegree map");
                *d -= 1;
                if *d == 0 {
                    queue.push(*n);
                }
            }
        }
    }
    visited == total
}

/// Number of distinct edges in the process-global order graph (test
/// instrumentation: proves the analysis actually observed the suite).
pub fn global_edge_count() -> usize {
    let g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    g.edges.values().map(HashSet::len).sum()
}

/// Run `f` with this thread's lock-order edges recorded into a fresh
/// isolated graph instead of the process-global one, and return the
/// cycles and wait reports it produced. This is how the deliberate
/// AB/BA fixture is tested without contaminating the global graph.
pub fn with_isolated_graph<R>(f: impl FnOnce() -> R) -> (R, Vec<CycleReport>, Vec<WaitReport>) {
    let graph = Rc::new(RefCell::new(Graph::default()));
    ISOLATED.with(|g| *g.borrow_mut() = Some(graph.clone()));
    let out = f();
    ISOLATED.with(|g| *g.borrow_mut() = None);
    let g = graph.borrow();
    (out, g.cycles.clone(), g.waits.clone())
}
