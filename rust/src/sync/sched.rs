//! Controlled scheduler for model checking (the `nnscheck` analysis
//! layer, part 3 of 3; compiled only under `--features check`).
//!
//! Inside a model (entered via [`super::check::explore`] /
//! [`super::check::replay`]) the shim routes every lock acquire,
//! release, condvar wait/notify, atomic access, and thread spawn/join
//! here. The model's threads are real OS threads, but exactly one is
//! *current* at any instant: every other thread is blocked on the
//! scheduler's condvar waiting for its turn. At each **decision point**
//! (a shim operation) the current thread hands control to the scheduler,
//! which picks the next runnable thread:
//!
//! * **Random mode** — a SplitMix64 walk from a seed. One seed ⇒ one
//!   exact interleaving, so a failing seed is a complete reproduction
//!   recipe (loom/shuttle's key property).
//! * **Replay mode** — a forced decision prefix (from a recorded trace
//!   or a DFS frontier); beyond the prefix, decision 0 is taken, which
//!   by construction means "keep running the current thread" — i.e. the
//!   continuation is preemption-free. Bounded-preemption DFS in
//!   `check.rs` enumerates prefixes over this mode.
//!
//! What the scheduler understands:
//!
//! * **Mutexes** — ownership flags keyed by object id. A blocked
//!   acquirer is descheduled without touching the real lock (the real
//!   `std` lock is only taken once model ownership is won, when it is
//!   guaranteed free — the previous owner drops the real guard before
//!   ceding ownership), so the harness itself can never deadlock on a
//!   real primitive.
//! * **Condvars** — wait atomically releases the paired model mutex and
//!   blocks; notify makes one/all waiters runnable (the "one" is itself
//!   a recorded decision). A *timed* wait may be woken by the scheduler
//!   with a synthesized timeout, but only when nothing else can run —
//!   timeouts exist in these protocols as belt-and-braces recovery, and
//!   scheduling them eagerly would mask lost-wakeup bugs behind their
//!   own safety net.
//! * **Threads** — spawned threads run only when scheduled; join blocks
//!   until the target finishes. The model ends when every registered
//!   thread has finished.
//!
//! **Failure detection.** If no thread is runnable, none can time out,
//! and not all are finished — that is a deadlock (a lost wakeup is
//! precisely a deadlock in a model whose producer has no more wakes to
//! send). The failure, with a description of who is blocked on what, is
//! recorded and every thread is unwound via a sentinel panic
//! ([`CheckAbort`]) caught by the spawn wrappers. A panic inside model
//! code (an assertion about an invariant) is captured the same way. A
//! decision budget catches livelocks. The first failure wins; the
//! explore loop in `check.rs` turns it into a replayable counterexample.

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};

/// Sentinel panic payload used to unwind model threads after a failure
/// has been recorded. Spawn wrappers catch it and exit quietly.
pub(crate) struct CheckAbort;

static OBJECT_IDS: AtomicU64 = AtomicU64::new(1);

/// Unique id for every shim lock/condvar instance (model bookkeeping).
pub(crate) fn next_object_id() -> u64 {
    OBJECT_IDS.fetch_add(1, Ordering::Relaxed)
}

/// How the scheduler resolves decision points.
#[derive(Debug, Clone)]
pub enum Mode {
    /// Seeded SplitMix64 random walk.
    Random(u64),
    /// Forced decision prefix; choice 0 ("stay on the current thread")
    /// beyond it.
    Replay(Vec<u32>),
}

/// One recorded scheduling decision (the unit of traces and of the
/// bounded-preemption DFS frontier).
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// Number of options that were available.
    pub options: u32,
    /// Index picked (into the option order described below).
    pub picked: u32,
    /// The previously-current thread was among the options (so any
    /// `picked != 0` was a preemption).
    pub current_was_runnable: bool,
}

/// Why a model execution failed.
#[derive(Debug, Clone)]
pub enum FailureKind {
    Deadlock,
    Panic,
    StepBudget,
}

#[derive(Debug, Clone)]
pub struct Failure {
    pub kind: FailureKind,
    pub message: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Run {
    Runnable,
    BlockedLock(u64),
    BlockedCv { cv: u64, timed: bool },
    BlockedJoin(usize),
    Finished,
}

struct ThreadState {
    run: Run,
    /// Set when a condvar wake came from a notify (vs a synthesized
    /// timeout) — read back by `condvar_wait`.
    woke_by_notify: bool,
    #[allow(dead_code)]
    name: Option<String>,
}

struct State {
    threads: Vec<ThreadState>,
    current: usize,
    /// Model-level mutex ownership, keyed by object id.
    owners: HashMap<u64, usize>,
    mode: Mode,
    /// Position in the Replay prefix / decisions consumed so far.
    cursor: usize,
    trace: Vec<Decision>,
    max_decisions: usize,
    failure: Option<Failure>,
    live: usize,
}

/// Shared scheduler handle for one model execution.
pub(crate) struct Ctl {
    m: StdMutex<State>,
    cv: StdCondvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Ctl>, usize)>> = const { RefCell::new(None) };
}

/// True when the calling thread belongs to an active model.
#[inline]
pub(crate) fn in_model() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

fn current_model() -> Option<(Arc<Ctl>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

fn set_current(v: Option<(Arc<Ctl>, usize)>) {
    CURRENT.with(|c| *c.borrow_mut() = v);
}

fn splitmix64(s: &mut u64) -> u64 {
    *s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *s;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Ctl {
    fn new(mode: Mode, max_decisions: usize) -> Arc<Ctl> {
        Arc::new(Ctl {
            m: StdMutex::new(State {
                threads: Vec::new(),
                current: 0,
                owners: HashMap::new(),
                mode,
                cursor: 0,
                trace: Vec::new(),
                max_decisions,
                failure: None,
                live: 0,
            }),
            cv: StdCondvar::new(),
        })
    }

    fn lock_state(&self) -> StdMutexGuard<'_, State> {
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Resolve one decision among `options.len()` choices.
    fn decide(&self, st: &mut State, options: u32, current_was_runnable: bool) -> u32 {
        let cursor = st.cursor;
        let picked = match st.mode {
            Mode::Random(ref mut s) => (splitmix64(s) % options as u64) as u32,
            Mode::Replay(ref forced) => {
                let c = forced.get(cursor).copied().unwrap_or(0);
                c.min(options - 1)
            }
        };
        st.cursor += 1;
        st.trace.push(Decision {
            options,
            picked,
            current_was_runnable,
        });
        picked
    }

    /// Pick the next thread to run. `prev` is the thread that held the
    /// token (it may be runnable — a voluntary yield — or blocked or
    /// finished). Sets `st.current`; on dead ends records a failure.
    fn advance(&self, st: &mut State) {
        if st.failure.is_some() {
            return;
        }
        if st.trace.len() >= st.max_decisions {
            self.fail(
                st,
                FailureKind::StepBudget,
                format!(
                    "no verdict within the decision budget ({}) — livelock or runaway model",
                    st.max_decisions
                ),
            );
            return;
        }
        let prev = st.current;
        let mut runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.run == Run::Runnable)
            .map(|(i, _)| i)
            .collect();
        let current_was_runnable = runnable.contains(&prev);
        if current_was_runnable {
            // Option order: current-first, so choice 0 is always "no
            // preemption" (the DFS baseline) and any other choice is a
            // preemption.
            runnable.retain(|&t| t != prev);
            runnable.insert(0, prev);
        }
        if runnable.is_empty() {
            // Nothing runnable: synthesize a timeout if a timed waiter
            // exists, otherwise this is a terminal state.
            let timed: Vec<usize> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| matches!(t.run, Run::BlockedCv { timed: true, .. }))
                .map(|(i, _)| i)
                .collect();
            if !timed.is_empty() {
                let pick = self.decide(st, timed.len() as u32, false) as usize;
                let tid = timed[pick];
                st.threads[tid].run = Run::Runnable;
                st.threads[tid].woke_by_notify = false;
                st.current = tid;
                return;
            }
            if st.threads.iter().all(|t| t.run == Run::Finished) {
                st.current = usize::MAX; // model complete
                return;
            }
            let who: Vec<String> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.run != Run::Finished)
                .map(|(i, t)| format!("t{i} {:?}", t.run))
                .collect();
            self.fail(
                st,
                FailureKind::Deadlock,
                format!("deadlock: no runnable thread [{}]", who.join(", ")),
            );
            return;
        }
        let pick = self.decide(st, runnable.len() as u32, current_was_runnable) as usize;
        st.current = runnable[pick];
    }

    fn fail(&self, st: &mut State, kind: FailureKind, message: String) {
        if st.failure.is_none() {
            st.failure = Some(Failure { kind, message });
        }
        st.current = usize::MAX;
    }

    /// Block until it is `me`'s turn. Panics with [`CheckAbort`] if the
    /// model failed in the meantime.
    fn wait_turn<'a>(&'a self, mut st: StdMutexGuard<'a, State>, me: usize) -> StdMutexGuard<'a, State> {
        loop {
            if st.failure.is_some() {
                drop(st);
                std::panic::panic_any(CheckAbort);
            }
            if st.current == me {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-panicking variant for thread startup: `Err(())` on abort.
    fn wait_turn_soft<'a>(
        &'a self,
        mut st: StdMutexGuard<'a, State>,
        me: usize,
    ) -> Result<StdMutexGuard<'a, State>, ()> {
        loop {
            if st.failure.is_some() {
                return Err(());
            }
            if st.current == me {
                return Ok(st);
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Decision point: reschedule with the calling thread still runnable.
pub(crate) fn yield_point() {
    let Some((ctl, me)) = current_model() else {
        return;
    };
    let mut st = ctl.lock_state();
    if st.failure.is_some() {
        drop(st);
        std::panic::panic_any(CheckAbort);
    }
    ctl.advance(&mut st);
    ctl.cv.notify_all();
    let st = ctl.wait_turn(st, me);
    drop(st);
}

/// Acquire model ownership of mutex `id`, blocking (in model terms)
/// while another thread owns it. The caller takes the real lock only
/// after this returns.
pub(crate) fn lock_acquire(id: u64) {
    let Some((ctl, me)) = current_model() else {
        return;
    };
    let mut st = ctl.lock_state();
    loop {
        if st.failure.is_some() {
            drop(st);
            std::panic::panic_any(CheckAbort);
        }
        if !st.owners.contains_key(&id) {
            st.owners.insert(id, me);
            return;
        }
        st.threads[me].run = Run::BlockedLock(id);
        ctl.advance(&mut st);
        ctl.cv.notify_all();
        st = ctl.wait_turn(st, me);
    }
}

/// Release model ownership of mutex `id` and wake its waiters. Called
/// from guard drops — which also run during abort unwinding, so this
/// must never panic: after a failure it only releases and returns.
pub(crate) fn lock_release(id: u64) {
    let Some((ctl, me)) = current_model() else {
        return;
    };
    let mut st = ctl.lock_state();
    st.owners.remove(&id);
    for t in st.threads.iter_mut() {
        if t.run == Run::BlockedLock(id) {
            t.run = Run::Runnable;
        }
    }
    if st.failure.is_some() {
        ctl.cv.notify_all();
        return;
    }
    // The release is itself a decision point (release-then-reacquire
    // races are a classic interleaving family).
    ctl.advance(&mut st);
    ctl.cv.notify_all();
    let st = ctl.wait_turn(st, me);
    drop(st);
}

/// Atomically release mutex `mx`, wait on condvar `cv`, then re-acquire
/// `mx`. Returns true when the wake was a synthesized timeout.
pub(crate) fn condvar_wait(cv: u64, mx: u64, timed: bool) -> bool {
    let Some((ctl, me)) = current_model() else {
        return false;
    };
    {
        let mut st = ctl.lock_state();
        if st.failure.is_some() {
            drop(st);
            std::panic::panic_any(CheckAbort);
        }
        st.owners.remove(&mx);
        for t in st.threads.iter_mut() {
            if t.run == Run::BlockedLock(mx) {
                t.run = Run::Runnable;
            }
        }
        st.threads[me].run = Run::BlockedCv { cv, timed };
        st.threads[me].woke_by_notify = false;
        ctl.advance(&mut st);
        ctl.cv.notify_all();
        let st = ctl.wait_turn(st, me);
        drop(st);
    }
    let timed_out = {
        let st = ctl.lock_state();
        !st.threads[me].woke_by_notify
    };
    lock_acquire(mx);
    timed_out
}

/// Notify one/all waiters of condvar `cv`. Choosing *which* single
/// waiter wakes is a recorded decision.
pub(crate) fn condvar_notify(cv: u64, all: bool) {
    let Some((ctl, me)) = current_model() else {
        return;
    };
    let mut st = ctl.lock_state();
    if st.failure.is_some() {
        drop(st);
        std::panic::panic_any(CheckAbort);
    }
    let waiters: Vec<usize> = st
        .threads
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t.run, Run::BlockedCv { cv: c, .. } if c == cv))
        .map(|(i, _)| i)
        .collect();
    if !waiters.is_empty() {
        if all {
            for &w in &waiters {
                st.threads[w].run = Run::Runnable;
                st.threads[w].woke_by_notify = true;
            }
        } else {
            let pick = ctl.decide(&mut st, waiters.len() as u32, false) as usize;
            let w = waiters[pick];
            st.threads[w].run = Run::Runnable;
            st.threads[w].woke_by_notify = true;
        }
    }
    ctl.advance(&mut st);
    ctl.cv.notify_all();
    let st = ctl.wait_turn(st, me);
    drop(st);
}

/// Spawn a model thread. The child registers with the scheduler, waits
/// for its first turn, runs `f` under `catch_unwind`, and reports
/// panics (other than [`CheckAbort`]) as model failures.
pub(crate) fn spawn_model<F, T>(
    f: F,
    name: Option<String>,
) -> (usize, std::thread::JoinHandle<std::thread::Result<T>>)
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (ctl, _me) = current_model().expect("spawn_model outside a model");
    let tid = {
        let mut st = ctl.lock_state();
        st.threads.push(ThreadState {
            run: Run::Runnable,
            woke_by_notify: false,
            name: name.clone(),
        });
        st.live += 1;
        st.threads.len() - 1
    };
    let ctl_child = ctl.clone();
    let handle = std::thread::Builder::new()
        .name(name.unwrap_or_else(|| format!("nnscheck-{tid}")))
        .spawn(move || {
            set_current(Some((ctl_child.clone(), tid)));
            let first = {
                let st = ctl_child.lock_state();
                ctl_child.wait_turn_soft(st, tid)
            };
            let result: std::thread::Result<T> = match first {
                Err(()) => Err(Box::new(CheckAbort) as Box<dyn std::any::Any + Send>),
                Ok(st) => {
                    drop(st);
                    match catch_unwind(AssertUnwindSafe(f)) {
                        Ok(v) => Ok(v),
                        Err(payload) => {
                            if !payload.is::<CheckAbort>() {
                                let msg = panic_message(&payload);
                                let mut st = ctl_child.lock_state();
                                ctl_child.fail(&mut st, FailureKind::Panic, msg);
                                ctl_child.cv.notify_all();
                            }
                            Err(payload)
                        }
                    }
                }
            };
            finish_thread(&ctl_child, tid);
            set_current(None);
            result
        })
        .expect("spawn model thread");
    // Registering the child is a decision point for the parent: the
    // child may run before the parent's next instruction, or not.
    yield_point();
    (tid, handle)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked".to_string()
    }
}

/// Mark `tid` finished, wake its joiners, and hand the token onward.
/// The exiting thread does not wait for a turn again.
fn finish_thread(ctl: &Arc<Ctl>, tid: usize) {
    let mut st = ctl.lock_state();
    st.threads[tid].run = Run::Finished;
    st.live = st.live.saturating_sub(1);
    for t in st.threads.iter_mut() {
        if t.run == Run::BlockedJoin(tid) {
            t.run = Run::Runnable;
        }
    }
    if st.failure.is_none() && st.current == tid {
        ctl.advance(&mut st);
    }
    ctl.cv.notify_all();
}

/// Block (in model terms) until thread `tid` finishes.
pub(crate) fn join_model(target: usize) {
    let Some((ctl, me)) = current_model() else {
        return;
    };
    let mut st = ctl.lock_state();
    if st.failure.is_some() {
        drop(st);
        std::panic::panic_any(CheckAbort);
    }
    if st.threads[target].run == Run::Finished {
        drop(st);
        yield_point();
        return;
    }
    st.threads[me].run = Run::BlockedJoin(target);
    ctl.advance(&mut st);
    ctl.cv.notify_all();
    let st = ctl.wait_turn(st, me);
    drop(st);
}

/// Outcome of one controlled execution.
pub struct RunReport {
    pub failure: Option<Failure>,
    pub trace: Vec<Decision>,
}

/// Run `f` as the root thread (tid 0) of a fresh model and drive it to
/// completion. Must not be called from inside another model; callers
/// (`check::explore`) serialize executions process-wide.
pub(crate) fn run_model<F>(mode: Mode, max_decisions: usize, f: F) -> RunReport
where
    F: FnOnce() + std::panic::UnwindSafe,
{
    assert!(
        !in_model(),
        "nested nnscheck models are not supported (explore inside explore)"
    );
    let ctl = Ctl::new(mode, max_decisions);
    {
        let mut st = ctl.lock_state();
        st.threads.push(ThreadState {
            run: Run::Runnable,
            woke_by_notify: false,
            name: Some("root".to_string()),
        });
        st.live += 1;
        st.current = 0;
    }
    set_current(Some((ctl.clone(), 0)));
    let result = catch_unwind(f);
    if let Err(payload) = result {
        if !payload.is::<CheckAbort>() {
            let msg = panic_message(&*payload);
            let mut st = ctl.lock_state();
            ctl.fail(&mut st, FailureKind::Panic, msg);
            ctl.cv.notify_all();
        }
    }
    finish_thread(&ctl, 0);
    set_current(None);
    // Drain: keep the scheduler alive until every model thread exits
    // (threads a failing model never joined included — a failure set
    // above unwinds them at their next decision point).
    let mut st = ctl.lock_state();
    while st.live > 0 {
        st = ctl.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    RunReport {
        failure: st.failure.clone(),
        trace: st.trace.clone(),
    }
}
