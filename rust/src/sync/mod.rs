//! `nns_sync` — the crate-wide synchronization shim (the `nnscheck`
//! analysis layer, part 1 of 3; see also [`lockdep`] and [`check`]).
//!
//! Every lock, condvar, atomic, and thread spawn in the concurrency core
//! (`pipeline/executor.rs`, `pipeline/stream.rs`, `pipeline/hub.rs`,
//! `net/transport.rs`, `devices/npu.rs`, `tensor/pool.rs`,
//! `runtime/pool.rs`, `net/mod.rs`, `net/registry.rs`) goes through this
//! module instead of `std::sync`. In a plain build the types below are
//! `#[inline]` delegations to their `std` counterparts — no extra state
//! is consulted on any acquire or release, so release-mode behavior and
//! performance are those of `std::sync`. The shim earns its keep in two
//! instrumented configurations:
//!
//! * **debug builds** (`cfg(debug_assertions)`) run the lock-order
//!   analysis in [`lockdep`]: every `Mutex`/`RwLock` construction site
//!   becomes a stable lock *class* (`file:line:column`, captured with
//!   `#[track_caller]`), every acquisition made while another shim lock
//!   is held records a directed order edge, and any cycle — an AB/BA
//!   inversion — is reported with both sites the moment the closing
//!   edge appears. On by default in every debug build, not just under
//!   `check`; disable with `NNS_LOCKDEP=0`.
//!
//! * **`--features check`** additionally compiles the controlled
//!   scheduler in [`sched`]: inside a [`check::explore`] /
//!   [`check::replay`] model, every acquire/release/wait/notify/spawn
//!   becomes a decision point of a deterministic seeded scheduler that
//!   serializes the model's threads and explores their interleavings
//!   (seeded random walks plus bounded-preemption DFS), replaying any
//!   failure from its seed. Outside a model the shim still passes
//!   straight through, so the ordinary suite runs unchanged with the
//!   feature enabled.
//!
//! The API mirrors `std::sync` closely enough that migration is an
//! import swap: `lock()` returns `LockResult` (reusing
//! `std::sync::PoisonError`, so the crate's poison-tolerant
//! `unwrap_or_else(|e| e.into_inner())` idiom keeps working), condvars
//! rewrap guards, and `thread::Builder` mirrors `std::thread::Builder`.
//! The one deliberate difference: [`WaitTimeoutResult`] is our own type
//! (std's has no public constructor, and the model scheduler must be
//! able to synthesize timeouts).

pub mod lockdep;

#[cfg(feature = "check")]
pub mod sched;

#[cfg(feature = "check")]
pub mod check;

use std::fmt;
use std::panic::Location;
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, RwLock as StdRwLock};
use std::sync::{LockResult, PoisonError};
use std::time::Duration;

/// Internal: unique object id for model-scheduler bookkeeping. Always
/// assigned (a plain counter bump at construction) so `Mutex::new` has
/// one shape in every build; only the model scheduler reads it.
#[cfg(feature = "check")]
#[inline]
fn next_object_id() -> u64 {
    sched::next_object_id()
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// Drop-in `std::sync::Mutex` with a stable lock-class identity.
///
/// The construction site (captured via `#[track_caller]`) is the lock's
/// *class* for lock-order analysis: all instances born at one line form
/// one class, which is exactly the granularity lock-ordering disciplines
/// are stated at ("the topic lock before any endpoint lock").
pub struct Mutex<T> {
    site: &'static Location<'static>,
    #[cfg(feature = "check")]
    model_id: u64,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    #[track_caller]
    pub fn new(value: T) -> Mutex<T> {
        Mutex {
            site: Location::caller(),
            #[cfg(feature = "check")]
            model_id: next_object_id(),
            inner: StdMutex::new(value),
        }
    }

    /// The construction site — the lock's class for order analysis.
    pub fn site(&self) -> &'static Location<'static> {
        self.site
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        #[cfg(debug_assertions)]
        lockdep::on_acquire(self.site);
        #[cfg(feature = "check")]
        if sched::in_model() {
            sched::yield_point();
            sched::lock_acquire(self.model_id);
            // The model owner released the real lock before ceding
            // ownership, so this never blocks.
            let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard {
                lock: self,
                inner: Some(g),
            });
        }
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard {
                lock: self,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(p.into_inner()),
            })),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").field("inner", &self.inner).finish()
    }
}

/// Guard for [`Mutex`]. Releases in the right order on drop: the real
/// guard first, then model ownership, then the lockdep held-stack entry.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    /// `None` once the real guard has been handed off (condvar wait) —
    /// the drop logic then has nothing left to release.
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<'a, T> std::ops::Deref for MutexGuard<'a, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<'a, T> std::ops::DerefMut for MutexGuard<'a, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<'a, T: fmt::Debug> fmt::Debug for MutexGuard<'a, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<'a, T> Drop for MutexGuard<'a, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g);
            #[cfg(feature = "check")]
            if sched::in_model() {
                sched::lock_release(self.lock.model_id);
            }
            #[cfg(debug_assertions)]
            lockdep::on_release(self.lock.site);
            #[cfg(all(not(debug_assertions), not(feature = "check")))]
            let _ = self.lock;
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Result of a [`Condvar::wait_timeout`]. Our own type rather than
/// `std::sync::WaitTimeoutResult` because the model scheduler has to be
/// able to construct one when it decides a timed wait "times out".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Drop-in `std::sync::Condvar`. Waits release and re-acquire the
/// guard's lock through the same instrumentation as [`Mutex::lock`], so
/// the lockdep held-stack stays truthful across the wait and the model
/// scheduler sees wait/notify as decision points.
pub struct Condvar {
    site: &'static Location<'static>,
    #[cfg(feature = "check")]
    model_id: u64,
    inner: StdCondvar,
}

impl Condvar {
    #[track_caller]
    pub fn new() -> Condvar {
        Condvar {
            site: Location::caller(),
            #[cfg(feature = "check")]
            model_id: next_object_id(),
            inner: StdCondvar::new(),
        }
    }

    /// The construction site of this condvar (reporting only).
    pub fn site(&self) -> &'static Location<'static> {
        self.site
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let mx = guard.lock;
        #[cfg(debug_assertions)]
        lockdep::on_wait(mx.site);
        #[cfg(feature = "check")]
        if sched::in_model() {
            drop(guard.inner.take());
            drop(guard);
            sched::condvar_wait(self.model_id, mx.model_id, false);
            #[cfg(debug_assertions)]
            lockdep::on_acquire(mx.site);
            let g = mx.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard {
                lock: mx,
                inner: Some(g),
            });
        }
        let inner = guard.inner.take().expect("guard already released");
        drop(guard);
        let res = self.inner.wait(inner);
        #[cfg(debug_assertions)]
        lockdep::on_acquire(mx.site);
        match res {
            Ok(g) => Ok(MutexGuard {
                lock: mx,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                lock: mx,
                inner: Some(p.into_inner()),
            })),
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let mx = guard.lock;
        #[cfg(debug_assertions)]
        lockdep::on_wait(mx.site);
        #[cfg(feature = "check")]
        if sched::in_model() {
            let _ = dur; // virtual time: the scheduler decides timeouts
            drop(guard.inner.take());
            drop(guard);
            let timed_out = sched::condvar_wait(self.model_id, mx.model_id, true);
            #[cfg(debug_assertions)]
            lockdep::on_acquire(mx.site);
            let g = mx.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok((
                MutexGuard {
                    lock: mx,
                    inner: Some(g),
                },
                WaitTimeoutResult { timed_out },
            ));
        }
        let inner = guard.inner.take().expect("guard already released");
        drop(guard);
        let res = self.inner.wait_timeout(inner, dur);
        #[cfg(debug_assertions)]
        lockdep::on_acquire(mx.site);
        match res {
            Ok((g, t)) => Ok((
                MutexGuard {
                    lock: mx,
                    inner: Some(g),
                },
                WaitTimeoutResult {
                    timed_out: t.timed_out(),
                },
            )),
            Err(p) => {
                let (g, t) = p.into_inner();
                Err(PoisonError::new((
                    MutexGuard {
                        lock: mx,
                        inner: Some(g),
                    },
                    WaitTimeoutResult {
                        timed_out: t.timed_out(),
                    },
                )))
            }
        }
    }

    pub fn notify_one(&self) {
        #[cfg(feature = "check")]
        if sched::in_model() {
            sched::condvar_notify(self.model_id, false);
            return;
        }
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        #[cfg(feature = "check")]
        if sched::in_model() {
            sched::condvar_notify(self.model_id, true);
            return;
        }
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

/// Drop-in `std::sync::RwLock` with a lock-class identity.
///
/// Under the model scheduler both `read` and `write` are treated as
/// exclusive acquisitions of one resource — a sound over-approximation
/// for deadlock/lost-wakeup checking (it removes reader-reader overlap,
/// which can hide interleavings but never invents a blocked-forever
/// state the real lock permits, as long as models do not rely on two
/// readers being inside the lock simultaneously).
pub struct RwLock<T> {
    site: &'static Location<'static>,
    #[cfg(feature = "check")]
    model_id: u64,
    inner: StdRwLock<T>,
}

impl<T> RwLock<T> {
    #[track_caller]
    pub fn new(value: T) -> RwLock<T> {
        RwLock {
            site: Location::caller(),
            #[cfg(feature = "check")]
            model_id: next_object_id(),
            inner: StdRwLock::new(value),
        }
    }

    /// The construction site — the lock's class for order analysis.
    pub fn site(&self) -> &'static Location<'static> {
        self.site
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        #[cfg(debug_assertions)]
        lockdep::on_acquire(self.site);
        #[cfg(feature = "check")]
        if sched::in_model() {
            sched::yield_point();
            sched::lock_acquire(self.model_id);
            let g = self.inner.read().unwrap_or_else(PoisonError::into_inner);
            return Ok(RwLockReadGuard {
                lock: self,
                inner: Some(g),
            });
        }
        match self.inner.read() {
            Ok(g) => Ok(RwLockReadGuard {
                lock: self,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(RwLockReadGuard {
                lock: self,
                inner: Some(p.into_inner()),
            })),
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        #[cfg(debug_assertions)]
        lockdep::on_acquire(self.site);
        #[cfg(feature = "check")]
        if sched::in_model() {
            sched::yield_point();
            sched::lock_acquire(self.model_id);
            let g = self.inner.write().unwrap_or_else(PoisonError::into_inner);
            return Ok(RwLockWriteGuard {
                lock: self,
                inner: Some(g),
            });
        }
        match self.inner.write() {
            Ok(g) => Ok(RwLockWriteGuard {
                lock: self,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                lock: self,
                inner: Some(p.into_inner()),
            })),
        }
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> RwLock<T> {
        RwLock::new(T::default())
    }
}

impl<T: fmt::Debug> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RwLock").field("inner", &self.inner).finish()
    }
}

macro_rules! rw_guard {
    ($name:ident, $std:ident) => {
        pub struct $name<'a, T> {
            lock: &'a RwLock<T>,
            inner: Option<std::sync::$std<'a, T>>,
        }

        impl<'a, T> std::ops::Deref for $name<'a, T> {
            type Target = T;

            fn deref(&self) -> &T {
                self.inner.as_ref().expect("guard already released")
            }
        }

        impl<'a, T> Drop for $name<'a, T> {
            fn drop(&mut self) {
                if let Some(g) = self.inner.take() {
                    drop(g);
                    #[cfg(feature = "check")]
                    if sched::in_model() {
                        sched::lock_release(self.lock.model_id);
                    }
                    #[cfg(debug_assertions)]
                    lockdep::on_release(self.lock.site);
                    #[cfg(all(not(debug_assertions), not(feature = "check")))]
                    let _ = self.lock;
                }
            }
        }
    };
}

rw_guard!(RwLockReadGuard, RwLockReadGuard);
rw_guard!(RwLockWriteGuard, RwLockWriteGuard);

impl<'a, T> std::ops::DerefMut for RwLockWriteGuard<'a, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

/// Shimmed atomics. Pass-through delegation in every build; under the
/// model scheduler each operation is additionally a scheduling decision
/// point, so flag/counter races (e.g. a `closed` flag checked against a
/// condvar protocol) are explored like lock operations.
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    macro_rules! atomic_int {
        ($name:ident, $std:ident, $prim:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                inner: std::sync::atomic::$std,
            }

            impl $name {
                pub const fn new(v: $prim) -> $name {
                    $name {
                        inner: std::sync::atomic::$std::new(v),
                    }
                }

                #[inline]
                fn touch(&self) {
                    #[cfg(feature = "check")]
                    if super::sched::in_model() {
                        super::sched::yield_point();
                    }
                }

                #[inline]
                pub fn load(&self, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.load(order)
                }

                #[inline]
                pub fn store(&self, v: $prim, order: Ordering) {
                    self.touch();
                    self.inner.store(v, order)
                }

                #[inline]
                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.swap(v, order)
                }

                #[inline]
                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.fetch_add(v, order)
                }

                #[inline]
                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.fetch_sub(v, order)
                }

                #[inline]
                pub fn fetch_max(&self, v: $prim, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.fetch_max(v, order)
                }

                #[inline]
                pub fn fetch_min(&self, v: $prim, order: Ordering) -> $prim {
                    self.touch();
                    self.inner.fetch_min(v, order)
                }
            }
        };
    }

    atomic_int!(AtomicU64, AtomicU64, u64);
    atomic_int!(AtomicUsize, AtomicUsize, usize);

    #[derive(Debug, Default)]
    pub struct AtomicBool {
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> AtomicBool {
            AtomicBool {
                inner: std::sync::atomic::AtomicBool::new(v),
            }
        }

        #[inline]
        fn touch(&self) {
            #[cfg(feature = "check")]
            if super::sched::in_model() {
                super::sched::yield_point();
            }
        }

        #[inline]
        pub fn load(&self, order: Ordering) -> bool {
            self.touch();
            self.inner.load(order)
        }

        #[inline]
        pub fn store(&self, v: bool, order: Ordering) {
            self.touch();
            self.inner.store(v, order)
        }

        #[inline]
        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            self.touch();
            self.inner.swap(v, order)
        }
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Shimmed thread API. Outside a model this is `std::thread`; inside a
/// model, spawns register the child with the controlled scheduler (it
/// runs only when scheduled), `sleep` is a pure yield (model time is
/// virtual), and `join` is a blocking scheduling operation.
pub mod thread {
    use std::time::Duration;

    enum Imp<T> {
        Std(std::thread::JoinHandle<T>),
        #[cfg(feature = "check")]
        Model {
            tid: usize,
            inner: std::thread::JoinHandle<std::thread::Result<T>>,
        },
    }

    /// Join handle mirroring `std::thread::JoinHandle`.
    pub struct JoinHandle<T> {
        imp: Imp<T>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.imp {
                Imp::Std(h) => h.join(),
                #[cfg(feature = "check")]
                Imp::Model { tid, inner } => {
                    super::sched::join_model(tid);
                    match inner.join() {
                        Ok(r) => r,
                        Err(e) => Err(e),
                    }
                }
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        #[cfg(feature = "check")]
        if super::sched::in_model() {
            let (tid, inner) = super::sched::spawn_model(f, None);
            return JoinHandle {
                imp: Imp::Model { tid, inner },
            };
        }
        JoinHandle {
            imp: Imp::Std(std::thread::spawn(f)),
        }
    }

    /// Mirror of `std::thread::Builder` (name only — that is all the
    /// crate uses).
    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Builder {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Builder {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            #[cfg(feature = "check")]
            if super::sched::in_model() {
                let (tid, inner) = super::sched::spawn_model(f, self.name);
                return Ok(JoinHandle {
                    imp: Imp::Model { tid, inner },
                });
            }
            let mut b = std::thread::Builder::new();
            if let Some(n) = self.name {
                b = b.name(n);
            }
            Ok(JoinHandle {
                imp: Imp::Std(b.spawn(f)?),
            })
        }
    }

    pub fn sleep(dur: Duration) {
        #[cfg(feature = "check")]
        if super::sched::in_model() {
            // Model time is virtual: a sleep provides no ordering, only
            // a scheduling decision point.
            super::sched::yield_point();
            return;
        }
        std::thread::sleep(dur)
    }

    pub fn yield_now() {
        #[cfg(feature = "check")]
        if super::sched::in_model() {
            super::sched::yield_point();
            return;
        }
        std::thread::yield_now()
    }
}
