//! `nns-launch`: the gst-launch-style CLI.
//!
//! ```text
//! nns-launch 'videotestsrc num-buffers=30 ! tensor_converter ! fakesink'
//! nns-launch --list            # registered elements
//! nns-launch --models          # artifacts in the manifest
//! ```

use nnstreamer::element::Registry;
use nnstreamer::pipeline::Pipeline;
use nnstreamer::runtime::ModelRegistry;

fn usage() -> ! {
    eprintln!(
        "usage: nns-launch [--list | --models | --stats] '<pipeline description>'\n\
         \n\
         examples:\n\
           nns-launch 'videotestsrc num-buffers=30 ! videoconvert format=RGB ! \\\n\
                       tensor_converter ! tensor_transform mode=normalize ! fakesink'\n\
           nns-launch --list"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let mut show_stats = false;
    let mut desc: Option<String> = None;
    for a in &args {
        match a.as_str() {
            "--list" => {
                for name in Registry::names() {
                    println!("{name}");
                }
                return;
            }
            "--models" => match ModelRegistry::global() {
                Ok(reg) => {
                    for name in reg.manifest().names() {
                        let spec = reg.manifest().get(name).unwrap();
                        println!(
                            "{name}\tin={:?}\tout={:?}\tflops={}",
                            spec.inputs
                                .iter()
                                .map(|i| i.to_string())
                                .collect::<Vec<_>>(),
                            spec.outputs
                                .iter()
                                .map(|i| i.to_string())
                                .collect::<Vec<_>>(),
                            spec.flops
                        );
                    }
                    return;
                }
                Err(e) => {
                    eprintln!("cannot open artifacts: {e}");
                    std::process::exit(1);
                }
            },
            "--stats" => show_stats = true,
            "--help" | "-h" => usage(),
            other if desc.is_none() => desc = Some(other.to_string()),
            other => {
                eprintln!("unexpected argument {other:?}");
                usage();
            }
        }
    }
    let Some(desc) = desc else { usage() };
    let mut pipeline = match Pipeline::parse(&desc) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("parse error: {e}");
            std::process::exit(1);
        }
    };
    match pipeline.run() {
        Ok(report) => {
            eprintln!(
                "pipeline finished in {:.3}s (cpu {:.1}%, peak rss {:.1} MiB)",
                report.wall.as_secs_f64(),
                report.cpu_percent,
                report.peak_rss_mib
            );
            if show_stats {
                for e in &report.elements {
                    eprintln!(
                        "  {:24} in={:6} out={:6} drop={:4} busy_cpu={:8.3}ms busy_npu={:8.3}ms",
                        e.name,
                        e.buffers_in(),
                        e.buffers_out(),
                        e.dropped(),
                        e.busy_cpu().as_secs_f64() * 1e3,
                        e.busy_npu().as_secs_f64() * 1e3,
                    );
                }
            }
        }
        Err(e) => {
            eprintln!("pipeline error: {e}");
            std::process::exit(1);
        }
    }
}
