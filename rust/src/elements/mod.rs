//! Built-in element set.
//!
//! Two families, mirroring the paper's Fig. 1:
//! * **off-the-shelf stream elements** (what NNStreamer inherits from
//!   GStreamer): sources, sinks, `queue`, `tee`, `valve`, selectors,
//!   `videoconvert`, `videoscale`, ...
//! * **NNStreamer elements** (the paper's contribution): `tensor_converter`,
//!   `tensor_filter`, `tensor_decoder`, `tensor_transform`, `tensor_mux`,
//!   `tensor_demux`, `tensor_merge`, `tensor_split`, `tensor_aggregator`,
//!   `tensor_rate`, `tensor_if`, `tensor_repo_src`/`sink`, `tensor_sink`,
//!   `sensorsrc` (the Tensor-Src-IIO analog).

pub mod aggregator;
pub mod converter;
pub mod decoder;
pub mod filter;
pub mod flow;
pub mod merge;
pub mod mux;
pub mod query;
pub mod rate;
pub mod repo;
pub mod sinks;
pub mod sources;
pub mod sync;
pub mod tensor_if;
pub mod transform;
pub mod videofilters;

use std::collections::HashMap;

use crate::element::Element;

type Factory = Box<dyn Fn() -> Box<dyn Element> + Send + Sync>;

macro_rules! reg {
    ($m:expr, $name:literal, $ctor:expr) => {
        $m.insert(
            $name.to_string(),
            Box::new(|| Box::new($ctor) as Box<dyn Element>) as Factory,
        );
    };
}

/// Register every built-in element factory (called once by the registry).
pub fn register_builtins(m: &mut HashMap<String, Factory>) {
    // sources
    reg!(m, "videotestsrc", sources::VideoTestSrc::new());
    reg!(m, "appsrc", sources::AppSrc::new());
    reg!(m, "sensorsrc", sources::SensorSrc::new());
    reg!(m, "filesrc", sources::FileSrc::new());
    // sinks
    reg!(m, "fakesink", sinks::FakeSink::new());
    reg!(m, "appsink", sinks::AppSink::new());
    reg!(m, "tensor_sink", sinks::TensorSink::new());
    reg!(m, "filesink", sinks::FileSink::new());
    // flow utilities
    reg!(m, "queue", flow::Queue::new());
    reg!(m, "tee", flow::Tee::new());
    reg!(m, "valve", flow::Valve::new());
    reg!(m, "capsfilter", flow::CapsFilter::new());
    reg!(m, "input-selector", flow::InputSelector::new());
    reg!(m, "output-selector", flow::OutputSelector::new());
    // video filters
    reg!(m, "videoconvert", videofilters::VideoConvert::new());
    reg!(m, "videoscale", videofilters::VideoScale::new());
    reg!(m, "videocrop", videofilters::VideoCrop::new());
    reg!(m, "videoflip", videofilters::VideoFlip::new());
    // NNStreamer elements
    reg!(m, "tensor_converter", converter::TensorConverter::new());
    reg!(m, "tensor_decoder", decoder::TensorDecoder::new());
    reg!(m, "tensor_filter", filter::TensorFilter::new());
    reg!(m, "tensor_transform", transform::TensorTransform::new());
    reg!(m, "tensor_mux", mux::TensorMux::new());
    reg!(m, "tensor_demux", mux::TensorDemux::new());
    reg!(m, "tensor_merge", merge::TensorMerge::new());
    reg!(m, "tensor_split", merge::TensorSplit::new());
    reg!(m, "tensor_aggregator", aggregator::TensorAggregator::new());
    reg!(m, "tensor_rate", rate::TensorRate::new());
    reg!(m, "tensor_if", tensor_if::TensorIf::new());
    reg!(m, "tensor_repo_src", repo::TensorRepoSrc::new());
    reg!(m, "tensor_repo_sink", repo::TensorRepoSink::new());
    // among-device stream endpoints (tensor-query pub/sub)
    reg!(m, "tensor_query_serversrc", query::TensorQueryServerSrc::new());
    reg!(m, "tensor_query_serversink", query::TensorQueryServerSink::new());
    reg!(m, "tensor_query_client", query::TensorQueryClient::new());
}
