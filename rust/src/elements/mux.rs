//! `tensor_mux` / `tensor_demux`: bundle N `other/tensor` streams into one
//! `other/tensors` stream and back (§III). Zero-copy: chunks move, payloads
//! don't.

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, MAX_TENSORS};

use super::sync::{SyncPolicy, Synchronizer};

/// Typed properties of [`TensorMux`].
#[derive(Debug, Clone, Copy)]
pub struct TensorMuxProps {
    /// Stream synchronization policy (`sync-mode=slowest|fastest|base[:k]`).
    pub sync_mode: SyncPolicy,
}

impl Default for TensorMuxProps {
    fn default() -> Self {
        Self {
            sync_mode: SyncPolicy::Slowest,
        }
    }
}

impl Props for TensorMuxProps {
    const FACTORY: &'static str = "tensor_mux";
    const KEYS: &'static [&'static str] = &["sync-mode"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "sync-mode" | "sync_mode" => self.sync_mode = SyncPolicy::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorMux::from_props(self)?))
    }
}

/// N×`other/tensor` → 1×`other/tensors`.
pub struct TensorMux {
    props: TensorMuxProps,
    sync: Option<Synchronizer>,
}

impl TensorMux {
    pub fn new() -> Self {
        Self::from_props(TensorMuxProps::default()).expect("defaults are valid")
    }
}

impl FromProps for TensorMux {
    type Props = TensorMuxProps;

    fn from_props(props: TensorMuxProps) -> Result<Self> {
        Ok(Self { props, sync: None })
    }
}

impl Default for TensorMux {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorMux {
    fn type_name(&self) -> &'static str {
        "tensor_mux"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: MAX_TENSORS }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let mut infos = Vec::new();
        let mut fps = 0u64;
        for c in in_caps {
            match c {
                Caps::Tensor { info, fps_millis } => {
                    infos.push(info.clone());
                    fps = fps.max(*fps_millis);
                }
                Caps::Tensors {
                    infos: i,
                    fps_millis,
                } => {
                    infos.extend(i.iter().cloned());
                    fps = fps.max(*fps_millis);
                }
                other => {
                    return Err(Error::Negotiation(format!(
                        "tensor_mux pads need tensors, got {other}"
                    )))
                }
            }
        }
        if infos.len() > MAX_TENSORS {
            return Err(Error::Negotiation(format!(
                "tensor_mux: {} tensors exceed the {MAX_TENSORS}-chunk frame limit",
                infos.len()
            )));
        }
        self.sync = Some(Synchronizer::new(self.props.sync_mode, in_caps.len()));
        // output rate depends on the policy; expose variable (0) unless base
        let out_fps = match self.props.sync_mode {
            SyncPolicy::Base(k) => in_caps
                .get(k)
                .and_then(|c| c.fps())
                .map(|f| (f * 1000.0) as u64)
                .unwrap_or(0),
            _ => 0,
        };
        Ok(vec![
            Caps::Tensors {
                infos,
                fps_millis: out_fps
            };
            n_srcs.max(1)
        ])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let sync = self
            .sync
            .as_mut()
            .ok_or_else(|| Error::element("tensor_mux", "not negotiated"))?;
        match item {
            Item::Buffer(buf) => sync.push(pad, buf),
            Item::Eos => sync.set_eos(pad),
        }
        while let Some(set) = sync.try_collect() {
            let bundled = Buffer::bundle(set)?;
            ctx.push(0, bundled)?;
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`TensorDemux`] (none).
#[derive(Debug, Clone, Copy, Default)]
pub struct TensorDemuxProps;

impl Props for TensorDemuxProps {
    const FACTORY: &'static str = "tensor_demux";
    const KEYS: &'static [&'static str] = &[];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        Err(unknown_property(Self::FACTORY, Self::KEYS, key, value))
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorDemux::from_props(self)?))
    }
}

/// 1×`other/tensors` → N×`other/tensor` (zero-copy unbundle).
pub struct TensorDemux;

impl TensorDemux {
    pub fn new() -> Self {
        TensorDemux
    }
}

impl FromProps for TensorDemux {
    type Props = TensorDemuxProps;

    fn from_props(_props: TensorDemuxProps) -> Result<Self> {
        Ok(TensorDemux)
    }
}

impl Default for TensorDemux {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorDemux {
    fn type_name(&self) -> &'static str {
        "tensor_demux"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: MAX_TENSORS }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Tensors { infos, fps_millis } = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "tensor_demux needs other/tensors input, got {}",
                in_caps[0]
            )));
        };
        if n_srcs > infos.len() {
            return Err(Error::Negotiation(format!(
                "tensor_demux: {} src pads but only {} tensors",
                n_srcs,
                infos.len()
            )));
        }
        Ok(infos
            .iter()
            .map(|i| Caps::Tensor {
                info: i.clone(),
                fps_millis: *fps_millis,
            })
            .collect())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let parts = buf.unbundle();
            for (i, part) in parts.into_iter().enumerate() {
                if i < ctx.n_src_pads() {
                    ctx.push(i, part)?;
                }
            }
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::ctx_with_outputs;
    use crate::tensor::DType;

    #[test]
    fn mux_negotiates_tensors_caps() {
        let mut m = TensorMux::new();
        let a = Caps::tensor(DType::F32, [4], 30.0);
        let b = Caps::tensor(DType::U8, [8, 2], 30.0);
        let out = m.negotiate(&[a, b], 1).unwrap();
        match &out[0] {
            Caps::Tensors { infos, .. } => {
                assert_eq!(infos.len(), 2);
                assert_eq!(infos[1].dims.as_slice(), &[8, 2]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn mux_then_demux_roundtrip_zero_copy() {
        let mut m = TensorMux::new();
        let a = Caps::tensor(DType::F32, [1], 30.0);
        let b = Caps::tensor(DType::F32, [1], 30.0);
        m.negotiate(&[a, b], 1).unwrap();

        let (mut ctx, rxs) = ctx_with_outputs(1);
        let b0 = Buffer::from_f32(0, &[1.0]);
        let b1 = Buffer::from_f32(0, &[2.0]);
        let (p0, p1) = (b0.chunk().ptr(), b1.chunk().ptr());
        m.handle(0, Item::Buffer(b0), &mut ctx).unwrap();
        m.handle(1, Item::Buffer(b1), &mut ctx).unwrap();
        drop(ctx);
        let out = crate::element::testutil::drain(&rxs[0]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].chunks.len(), 2);
        assert_eq!(out[0].chunks[0].ptr(), p0);
        assert_eq!(out[0].chunks[1].ptr(), p1);

        // demux back
        let mut d = TensorDemux::new();
        let caps = Caps::Tensors {
            infos: vec![
                crate::tensor::TensorInfo::new(DType::F32, [1]),
                crate::tensor::TensorInfo::new(DType::F32, [1]),
            ],
            fps_millis: 30000,
        };
        d.negotiate(&[caps], 2).unwrap();
        let (mut ctx2, rxs2) = ctx_with_outputs(2);
        d.handle(0, Item::Buffer(out[0].clone()), &mut ctx2).unwrap();
        drop(ctx2);
        let o0 = crate::element::testutil::drain(&rxs2[0]);
        let o1 = crate::element::testutil::drain(&rxs2[1]);
        assert_eq!(o0[0].chunk().ptr(), p0);
        assert_eq!(o1[0].chunk().ptr(), p1);
    }
}
