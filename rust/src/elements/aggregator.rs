//! `tensor_aggregator`: temporal frame aggregation (§III).
//!
//! Merges `frames-in` consecutive frames into one output every
//! `frames-flush` frames (default: no overlap, i.e. flush = frames-in),
//! concatenating along `frames-dim`. E.g. merging frames 2i and 2i+1
//! halves the frame rate — the paper's LSTM/Seq2seq building block, and
//! the rate-decimation stage of the ARS pipeline (E2, Fig 3).

use std::collections::VecDeque;

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, TensorInfo};

use super::sources::parse_usize;

/// Typed properties of [`TensorAggregator`].
#[derive(Debug, Clone, Copy)]
pub struct TensorAggregatorProps {
    /// Frames merged per output (`frames-in`).
    pub frames_in: usize,
    /// Frames discarded per output; 0 = no overlap (`frames-flush`).
    pub frames_flush: usize,
    /// Concatenation axis, minor-first (`frames-dim`).
    pub frames_dim: usize,
}

impl Default for TensorAggregatorProps {
    fn default() -> Self {
        Self {
            frames_in: 2,
            frames_flush: 0,
            frames_dim: 0,
        }
    }
}

impl Props for TensorAggregatorProps {
    const FACTORY: &'static str = "tensor_aggregator";
    const KEYS: &'static [&'static str] = &["frames-in", "frames-flush", "frames-dim"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "frames-in" => self.frames_in = parse_usize(key, value)?.max(1),
            "frames-flush" => self.frames_flush = parse_usize(key, value)?,
            "frames-dim" => self.frames_dim = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorAggregator::from_props(self)?))
    }
}

pub struct TensorAggregator {
    props: TensorAggregatorProps,
    window: VecDeque<Buffer>,
    in_info: Option<TensorInfo>,
    out_info: Option<TensorInfo>,
}

impl FromProps for TensorAggregator {
    type Props = TensorAggregatorProps;

    fn from_props(mut props: TensorAggregatorProps) -> Result<Self> {
        // same clamp as the string front-end: at least one frame per window
        props.frames_in = props.frames_in.max(1);
        Ok(Self {
            props,
            window: VecDeque::new(),
            in_info: None,
            out_info: None,
        })
    }
}

impl TensorAggregator {
    pub fn new() -> Self {
        Self::from_props(TensorAggregatorProps::default()).expect("defaults are valid")
    }

    fn flush_count(&self) -> usize {
        if self.props.frames_flush == 0 {
            self.props.frames_in
        } else {
            self.props.frames_flush
        }
    }

    fn emit(&mut self, ctx: &mut Ctx) -> Result<()> {
        let info = self.in_info.as_ref().unwrap();
        let esz = info.size_bytes();
        let mut data = Vec::with_capacity(esz * self.props.frames_in);
        // concat along frames_dim: for dim 0..rank-1 we'd need interleaving;
        // aggregation along the *major* (last) axis is plain concatenation.
        // For minor axes, interleave elementwise rows.
        let rank = info.dims.rank();
        if self.props.frames_dim >= rank || self.props.frames_dim == rank.saturating_sub(1) + 1 {
            // append as a new major axis (or beyond current rank)
            for b in self.window.iter().take(self.props.frames_in) {
                data.extend_from_slice(b.chunk().as_bytes());
            }
        } else {
            // interleave along an existing axis
            let ebytes = info.dtype.size_bytes();
            let inner: usize = (0..self.props.frames_dim)
                .map(|d| info.dims.dim_or_1(d))
                .product::<usize>()
                * ebytes;
            let axis = info.dims.dim_or_1(self.props.frames_dim);
            let row = axis * inner;
            let outer = esz / row;
            data.resize(esz * self.props.frames_in, 0);
            let n = self.props.frames_in;
            for (fi, b) in self.window.iter().take(n).enumerate() {
                let src = b.chunk().as_bytes();
                for o in 0..outer {
                    let dst_off = o * row * n + fi * row;
                    data[dst_off..dst_off + row]
                        .copy_from_slice(&src[o * row..(o + 1) * row]);
                }
            }
        }
        let last = &self.window[self.props.frames_in - 1];
        let mut out = Buffer::single(last.pts_ns, Chunk::from_vec(data));
        out.seq = last.seq;
        for _ in 0..self.flush_count().min(self.window.len()) {
            self.window.pop_front();
        }
        ctx.push(0, out)
    }
}

impl Default for TensorAggregator {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorAggregator {
    fn type_name(&self) -> &'static str {
        "tensor_aggregator"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Tensor { info, fps_millis } = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "tensor_aggregator needs other/tensor input, got {}",
                in_caps[0]
            )));
        };
        self.in_info = Some(info.clone());
        let rank = info.dims.rank();
        let out_info = if self.props.frames_dim >= rank {
            // new axis appended
            TensorInfo::new(info.dtype, info.dims.with_dim(rank, self.props.frames_in))
        } else {
            TensorInfo::new(
                info.dtype,
                info.dims.with_dim(
                    self.props.frames_dim,
                    info.dims.dim_or_1(self.props.frames_dim) * self.props.frames_in,
                ),
            )
        };
        self.out_info = Some(out_info.clone());
        // output rate = input rate / flush
        let out_fps = fps_millis / self.flush_count().max(1) as u64;
        Ok(vec![
            Caps::Tensor {
                info: out_info,
                fps_millis: out_fps
            };
            n_srcs.max(1)
        ])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        self.window.push_back(buf);
        if self.window.len() >= self.props.frames_in {
            self.emit(ctx)?;
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};
    use crate::tensor::DType;

    #[test]
    fn aggregates_pairs_halving_rate() {
        let mut a = TensorAggregator::new();
        a.set_property("frames-in", "2").unwrap();
        a.set_property("frames-dim", "1").unwrap();
        let caps = Caps::tensor(DType::F32, [2], 30.0);
        let out = a.negotiate(&[caps], 1).unwrap();
        match &out[0] {
            Caps::Tensor { info, fps_millis } => {
                assert_eq!(info.dims.as_slice(), &[2, 2]);
                assert_eq!(*fps_millis, 15000, "rate halves");
            }
            _ => panic!(),
        }
        let (mut ctx, rxs) = ctx_with_outputs(1);
        for i in 0..4 {
            let b = Buffer::from_f32(i * 10, &[i as f32, i as f32 + 0.5]);
            a.handle(0, Item::Buffer(b), &mut ctx).unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].chunk().as_f32().unwrap(), &[0.0, 0.5, 1.0, 1.5]);
        assert_eq!(out[1].chunk().as_f32().unwrap(), &[2.0, 2.5, 3.0, 3.5]);
        // latest timestamp of each pair
        assert_eq!(out[0].pts_ns, 10);
        assert_eq!(out[1].pts_ns, 30);
    }

    #[test]
    fn sliding_window_with_flush() {
        let mut a = TensorAggregator::new();
        a.set_property("frames-in", "3").unwrap();
        a.set_property("frames-flush", "1").unwrap();
        a.set_property("frames-dim", "1").unwrap();
        let caps = Caps::tensor(DType::F32, [1], 10.0);
        a.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        for i in 0..5 {
            a.handle(0, Item::Buffer(Buffer::from_f32(i, &[i as f32])), &mut ctx)
                .unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        // windows [0,1,2], [1,2,3], [2,3,4]
        assert_eq!(out.len(), 3);
        assert_eq!(out[1].chunk().as_f32().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn interleave_minor_axis() {
        let mut a = TensorAggregator::new();
        a.set_property("frames-in", "2").unwrap();
        a.set_property("frames-dim", "0").unwrap();
        let caps = Caps::tensor(DType::F32, [2, 2], 0.0);
        let out_caps = a.negotiate(&[caps], 1).unwrap();
        match &out_caps[0] {
            Caps::Tensor { info, .. } => assert_eq!(info.dims.as_slice(), &[4, 2]),
            _ => panic!(),
        }
        let (mut ctx, rxs) = ctx_with_outputs(1);
        a.handle(0, Item::Buffer(Buffer::from_f32(0, &[1., 2., 3., 4.])), &mut ctx)
            .unwrap();
        a.handle(0, Item::Buffer(Buffer::from_f32(1, &[5., 6., 7., 8.])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        // rows interleaved along minor axis
        assert_eq!(
            out[0].chunk().as_f32().unwrap(),
            &[1., 2., 5., 6., 3., 4., 7., 8.]
        );
    }
}
