//! `tensor_merge` / `tensor_split`: dimension surgery across streams (§III).
//!
//! From two `3x4` streams, merge creates a `6x4`, `3x8`, or `3x4x2` stream
//! (concatenation along a chosen axis); split is the inverse. Unlike
//! mux/demux these *do* touch payload bytes (a single contiguous tensor
//! must be produced).

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, Dims, TensorInfo, MAX_TENSORS};

use super::sources::parse_usize;
use super::sync::{SyncPolicy, Synchronizer};

/// Typed properties of [`TensorMerge`].
#[derive(Debug, Clone, Copy)]
pub struct TensorMergeProps {
    /// Concatenation axis, minor-first (`option`).
    pub axis: usize,
    /// Stream synchronization policy (`sync-mode`).
    pub sync_mode: SyncPolicy,
}

impl Default for TensorMergeProps {
    fn default() -> Self {
        Self {
            axis: 0,
            sync_mode: SyncPolicy::Slowest,
        }
    }
}

impl Props for TensorMergeProps {
    const FACTORY: &'static str = "tensor_merge";
    const KEYS: &'static [&'static str] = &["mode", "option", "sync-mode"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "mode" => {
                if value != "linear" {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "only mode=linear supported".into(),
                    });
                }
            }
            "option" => self.axis = parse_usize(key, value)?,
            "sync-mode" | "sync_mode" => self.sync_mode = SyncPolicy::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorMerge::from_props(self)?))
    }
}

/// N×`other/tensor` → 1×`other/tensor`, concatenated along `option` axis.
pub struct TensorMerge {
    props: TensorMergeProps,
    sync: Option<Synchronizer>,
    in_infos: Vec<TensorInfo>,
    out_info: Option<TensorInfo>,
}

impl FromProps for TensorMerge {
    type Props = TensorMergeProps;

    fn from_props(props: TensorMergeProps) -> Result<Self> {
        Ok(Self {
            props,
            sync: None,
            in_infos: Vec::new(),
            out_info: None,
        })
    }
}

impl TensorMerge {
    pub fn new() -> Self {
        Self::from_props(TensorMergeProps::default()).expect("defaults are valid")
    }

    /// Compute the merged TensorInfo for concatenation along `axis`.
    fn merged_info(infos: &[TensorInfo], axis: usize) -> Result<TensorInfo> {
        let first = &infos[0];
        let rank = first.dims.rank().max(axis + 1);
        for info in infos.iter().skip(1) {
            if info.dtype != first.dtype {
                return Err(Error::Negotiation(
                    "tensor_merge inputs must share dtype".into(),
                ));
            }
            for d in 0..rank {
                if d != axis && info.dims.dim_or_1(d) != first.dims.dim_or_1(d) {
                    return Err(Error::Negotiation(format!(
                        "tensor_merge inputs disagree on dim {d}: {} vs {}",
                        first.dims, info.dims
                    )));
                }
            }
        }
        let total: usize = infos.iter().map(|i| i.dims.dim_or_1(axis)).sum();
        let mut dims: Vec<usize> = (0..rank).map(|d| first.dims.dim_or_1(d)).collect();
        dims[axis] = total;
        Ok(TensorInfo::new(first.dtype, Dims::new(&dims)))
    }
}

impl Default for TensorMerge {
    fn default() -> Self {
        Self::new()
    }
}

/// Concatenate raw tensor payloads along `axis` (minor-first dims).
///
/// Treat each tensor as [outer][axis_dim][inner] where inner = product of
/// dims below `axis` and outer = product of dims above it.
fn concat_axis(
    parts: &[(&[u8], &TensorInfo)],
    axis: usize,
    out_info: &TensorInfo,
) -> Vec<u8> {
    let esz = out_info.dtype.size_bytes();
    let inner: usize = (0..axis)
        .map(|d| out_info.dims.dim_or_1(d))
        .product::<usize>()
        * esz;
    let rank = out_info.dims.rank();
    let outer: usize = ((axis + 1)..rank)
        .map(|d| out_info.dims.dim_or_1(d))
        .product();
    let mut out = vec![0u8; out_info.size_bytes()];
    let out_axis = out_info.dims.dim_or_1(axis);
    let out_row = out_axis * inner;
    let mut axis_off = 0usize;
    for (data, info) in parts {
        let a = info.dims.dim_or_1(axis);
        let row = a * inner;
        for o in 0..outer {
            let src = &data[o * row..(o + 1) * row];
            let dst_off = o * out_row + axis_off * inner;
            out[dst_off..dst_off + row].copy_from_slice(src);
        }
        axis_off += a;
    }
    out
}

/// Slice a tensor into parts along `axis` with the given axis sizes.
fn split_axis(
    data: &[u8],
    in_info: &TensorInfo,
    axis: usize,
    sizes: &[usize],
) -> Vec<Vec<u8>> {
    let esz = in_info.dtype.size_bytes();
    let inner: usize = (0..axis)
        .map(|d| in_info.dims.dim_or_1(d))
        .product::<usize>()
        * esz;
    let rank = in_info.dims.rank().max(axis + 1);
    let outer: usize = ((axis + 1)..rank)
        .map(|d| in_info.dims.dim_or_1(d))
        .product();
    let in_axis = in_info.dims.dim_or_1(axis);
    let in_row = in_axis * inner;
    let mut outs = Vec::with_capacity(sizes.len());
    let mut axis_off = 0usize;
    for &a in sizes {
        let row = a * inner;
        let mut part = vec![0u8; row * outer];
        for o in 0..outer {
            let src_off = o * in_row + axis_off * inner;
            part[o * row..(o + 1) * row].copy_from_slice(&data[src_off..src_off + row]);
        }
        outs.push(part);
        axis_off += a;
    }
    outs
}

impl Element for TensorMerge {
    fn type_name(&self) -> &'static str {
        "tensor_merge"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: MAX_TENSORS }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let mut infos = Vec::new();
        let mut fps = 0u64;
        for c in in_caps {
            match c {
                Caps::Tensor { info, fps_millis } => {
                    infos.push(info.clone());
                    fps = fps.max(*fps_millis);
                }
                other => {
                    return Err(Error::Negotiation(format!(
                        "tensor_merge pads need other/tensor, got {other}"
                    )))
                }
            }
        }
        let out = Self::merged_info(&infos, self.props.axis)?;
        self.in_infos = infos;
        self.out_info = Some(out.clone());
        self.sync = Some(Synchronizer::new(self.props.sync_mode, in_caps.len()));
        Ok(vec![
            Caps::Tensor {
                info: out,
                fps_millis: fps
            };
            n_srcs.max(1)
        ])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let sync = self
            .sync
            .as_mut()
            .ok_or_else(|| Error::element("tensor_merge", "not negotiated"))?;
        match item {
            Item::Buffer(buf) => sync.push(pad, buf),
            Item::Eos => sync.set_eos(pad),
        }
        let out_info = self.out_info.as_ref().unwrap();
        while let Some(set) = sync.try_collect() {
            let pts = set.iter().map(|b| b.pts_ns).max().unwrap_or(0);
            let seq = set.iter().map(|b| b.seq).max().unwrap_or(0);
            let datas: Vec<(&[u8], &TensorInfo)> = set
                .iter()
                .zip(&self.in_infos)
                .map(|(b, i)| (b.chunk().as_bytes(), i))
                .collect();
            let merged = concat_axis(&datas, self.props.axis, out_info);
            let mut out = Buffer::single(pts, Chunk::from_vec(merged));
            out.seq = seq;
            ctx.push(0, out)?;
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`TensorSplit`].
#[derive(Debug, Clone, Default)]
pub struct TensorSplitProps {
    /// Split axis, minor-first (`option`).
    pub axis: usize,
    /// Per-pad axis sizes (`tensorseg=3:3:2`); empty = equal split.
    pub tensorseg: Vec<usize>,
}

impl Props for TensorSplitProps {
    const FACTORY: &'static str = "tensor_split";
    const KEYS: &'static [&'static str] = &["option", "tensorseg"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "option" => self.axis = parse_usize(key, value)?,
            "tensorseg" => {
                self.tensorseg = value
                    .split(':')
                    .map(|v| parse_usize(key, v))
                    .collect::<Result<_>>()?
            }
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorSplit::from_props(self)?))
    }
}

/// 1×`other/tensor` → N×`other/tensor`, sliced along `option` axis with
/// per-pad sizes from `tensorseg` (e.g. `tensorseg=3:3:2` splits axis into
/// 3,3,2). Default: equal split across attached pads.
pub struct TensorSplit {
    props: TensorSplitProps,
    in_info: Option<TensorInfo>,
    out_sizes: Vec<usize>,
}

impl FromProps for TensorSplit {
    type Props = TensorSplitProps;

    fn from_props(props: TensorSplitProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
            out_sizes: Vec::new(),
        })
    }
}

impl TensorSplit {
    pub fn new() -> Self {
        Self::from_props(TensorSplitProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorSplit {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorSplit {
    fn type_name(&self) -> &'static str {
        "tensor_split"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: MAX_TENSORS }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Tensor { info, fps_millis } = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "tensor_split needs other/tensor input, got {}",
                in_caps[0]
            )));
        };
        let axis_dim = info.dims.dim_or_1(self.props.axis);
        let seg = &self.props.tensorseg;
        let sizes: Vec<usize> = if !seg.is_empty() {
            if seg.iter().sum::<usize>() != axis_dim {
                return Err(Error::Negotiation(format!(
                    "tensorseg {seg:?} does not sum to axis dim {axis_dim}"
                )));
            }
            if seg.len() != n_srcs {
                return Err(Error::Negotiation(format!(
                    "tensorseg has {} parts but {} src pads attached",
                    seg.len(),
                    n_srcs
                )));
            }
            seg.clone()
        } else {
            if n_srcs == 0 || axis_dim % n_srcs != 0 {
                return Err(Error::Negotiation(format!(
                    "axis dim {axis_dim} not divisible by {n_srcs} pads (use tensorseg=)"
                )));
            }
            vec![axis_dim / n_srcs; n_srcs]
        };
        self.in_info = Some(info.clone());
        self.out_sizes = sizes.clone();
        Ok(sizes
            .iter()
            .map(|&a| Caps::Tensor {
                info: TensorInfo::new(info.dtype, info.dims.with_dim(self.props.axis, a)),
                fps_millis: *fps_millis,
            })
            .collect())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let info = self
            .in_info
            .as_ref()
            .ok_or_else(|| Error::element("tensor_split", "not negotiated"))?;
        let parts =
            split_axis(buf.chunk().as_bytes(), info, self.props.axis, &self.out_sizes);
        for (i, part) in parts.into_iter().enumerate() {
            let mut out = Buffer::single(buf.pts_ns, Chunk::from_vec(part));
            out.seq = buf.seq;
            ctx.push(i, out)?;
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};
    use crate::tensor::DType;

    #[test]
    fn merge_axis0_makes_6x4() {
        // two 3:4 tensors -> 6:4 on axis 0 (paper's example)
        let mut m = TensorMerge::new();
        m.set_property("mode", "linear").unwrap();
        m.set_property("option", "0").unwrap();
        let a = Caps::tensor(DType::F32, [3, 4], 30.0);
        let b = Caps::tensor(DType::F32, [3, 4], 30.0);
        let out = m.negotiate(&[a, b], 1).unwrap();
        match &out[0] {
            Caps::Tensor { info, .. } => assert_eq!(info.dims.as_slice(), &[6, 4]),
            _ => panic!(),
        }
    }

    #[test]
    fn merge_axis1_makes_3x8() {
        let mut m = TensorMerge::new();
        m.set_property("option", "1").unwrap();
        let a = Caps::tensor(DType::F32, [3, 4], 0.0);
        let b = Caps::tensor(DType::F32, [3, 4], 0.0);
        let out = m.negotiate(&[a, b], 1).unwrap();
        match &out[0] {
            Caps::Tensor { info, .. } => assert_eq!(info.dims.as_slice(), &[3, 8]),
            _ => panic!(),
        }
    }

    #[test]
    fn merge_axis2_makes_3x4x2() {
        let mut m = TensorMerge::new();
        m.set_property("option", "2").unwrap();
        let a = Caps::tensor(DType::F32, [3, 4], 0.0);
        let b = Caps::tensor(DType::F32, [3, 4], 0.0);
        let out = m.negotiate(&[a, b], 1).unwrap();
        match &out[0] {
            Caps::Tensor { info, .. } => assert_eq!(info.dims.as_slice(), &[3, 4, 2]),
            _ => panic!(),
        }
    }

    #[test]
    fn merge_concat_values_axis0() {
        let mut m = TensorMerge::new();
        m.set_property("option", "0").unwrap();
        let a = Caps::tensor(DType::F32, [2, 2], 0.0);
        let b = Caps::tensor(DType::F32, [2, 2], 0.0);
        m.negotiate(&[a, b], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        m.handle(0, Item::Buffer(Buffer::from_f32(0, &[1., 2., 3., 4.])), &mut ctx)
            .unwrap();
        m.handle(1, Item::Buffer(Buffer::from_f32(0, &[5., 6., 7., 8.])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        // minor-first: axis0 concat interleaves rows of the minor dim
        assert_eq!(
            out[0].chunk().as_f32().unwrap(),
            &[1., 2., 5., 6., 3., 4., 7., 8.]
        );
    }

    #[test]
    fn split_then_merge_roundtrip() {
        let mut s = TensorSplit::new();
        s.set_property("option", "0").unwrap();
        let caps = Caps::tensor(DType::F32, [4, 2], 0.0);
        let out_caps = s.negotiate(&[caps], 2).unwrap();
        assert_eq!(out_caps.len(), 2);
        let data = [1., 2., 3., 4., 5., 6., 7., 8.];
        let (mut ctx, rxs) = ctx_with_outputs(2);
        s.handle(0, Item::Buffer(Buffer::from_f32(0, &data)), &mut ctx)
            .unwrap();
        drop(ctx);
        let p0 = drain(&rxs[0]);
        let p1 = drain(&rxs[1]);
        assert_eq!(p0[0].chunk().as_f32().unwrap(), &[1., 2., 5., 6.]);
        assert_eq!(p1[0].chunk().as_f32().unwrap(), &[3., 4., 7., 8.]);

        // merging the parts back reproduces the original
        let mut m = TensorMerge::new();
        m.set_property("option", "0").unwrap();
        let a = Caps::tensor(DType::F32, [2, 2], 0.0);
        let b = Caps::tensor(DType::F32, [2, 2], 0.0);
        m.negotiate(&[a, b], 1).unwrap();
        let (mut ctx2, rxs2) = ctx_with_outputs(1);
        m.handle(0, Item::Buffer(p0[0].clone()), &mut ctx2).unwrap();
        m.handle(1, Item::Buffer(p1[0].clone()), &mut ctx2).unwrap();
        drop(ctx2);
        let merged = drain(&rxs2[0]);
        assert_eq!(merged[0].chunk().as_f32().unwrap(), &data);
    }

    #[test]
    fn split_rejects_bad_seg() {
        let mut s = TensorSplit::new();
        s.set_property("tensorseg", "3:2").unwrap();
        let caps = Caps::tensor(DType::F32, [4, 2], 0.0);
        assert!(s.negotiate(&[caps], 2).is_err());
    }

    #[test]
    fn merge_rejects_mismatched_dims() {
        let mut m = TensorMerge::new();
        m.set_property("option", "0").unwrap();
        let a = Caps::tensor(DType::F32, [3, 4], 0.0);
        let b = Caps::tensor(DType::F32, [3, 5], 0.0);
        assert!(m.negotiate(&[a, b], 1).is_err());
    }
}
