//! `tensor_rate`: rate override and QoS control (§III).
//!
//! Throttles (or pads) a tensor stream to a target frame rate using buffer
//! timestamps: excess frames are dropped, gaps are filled by duplicating
//! the previous frame (when `throttle=false`, only dropping happens).

use crate::element::props::{parse_bool, unknown_property};
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps};

use super::sources::parse_f64;

/// Typed properties of [`TensorRate`].
#[derive(Debug, Clone, Copy, Default)]
pub struct TensorRateProps {
    /// Target rate, frames/s; 0 keeps the input rate (`framerate`).
    pub framerate: f64,
    /// Duplicate frames to fill gaps on slow inputs (`throttle`).
    pub throttle: bool,
}

impl Props for TensorRateProps {
    const FACTORY: &'static str = "tensor_rate";
    const KEYS: &'static [&'static str] = &["framerate", "throttle"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "framerate" => {
                // accept "15" or "15/1"
                let v = value.split('/').next().unwrap_or(value);
                self.framerate = parse_f64(key, v)?;
            }
            "throttle" => self.throttle = parse_bool(value),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorRate::from_props(self)?))
    }
}

pub struct TensorRate {
    props: TensorRateProps,
    next_slot: u64,
    last: Option<Buffer>,
}

impl FromProps for TensorRate {
    type Props = TensorRateProps;

    fn from_props(props: TensorRateProps) -> Result<Self> {
        Ok(Self {
            props,
            next_slot: 0,
            last: None,
        })
    }
}

impl TensorRate {
    pub fn new() -> Self {
        Self::from_props(TensorRateProps::default()).expect("defaults are valid")
    }

    fn interval_ns(&self) -> u64 {
        (1e9 / self.props.framerate.max(1e-9)) as u64
    }
}

impl Default for TensorRate {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorRate {
    fn type_name(&self) -> &'static str {
        "tensor_rate"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let out = match (&in_caps[0], self.props.framerate) {
            (c, r) if r <= 0.0 => c.clone(),
            (Caps::Tensor { info, .. }, r) => Caps::Tensor {
                info: info.clone(),
                fps_millis: (r * 1000.0) as u64,
            },
            (Caps::Tensors { infos, .. }, r) => Caps::Tensors {
                infos: infos.clone(),
                fps_millis: (r * 1000.0) as u64,
            },
            (other, _) => {
                return Err(Error::Negotiation(format!(
                    "tensor_rate needs tensor input, got {other}"
                )))
            }
        };
        Ok(vec![out; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        if self.props.framerate <= 0.0 {
            ctx.push(0, buf)?;
            return Ok(Flow::Continue);
        }
        let interval = self.interval_ns();
        if buf.pts_ns + 1 < self.next_slot {
            // too early: drop (rate throttling)
            ctx.stats().record_drop();
            return Ok(Flow::Continue);
        }
        // fill gaps by duplicating the previous frame at slot boundaries
        if self.props.throttle {
            if let Some(last) = &self.last {
                while self.next_slot + interval <= buf.pts_ns {
                    let mut dup = last.clone();
                    dup.pts_ns = self.next_slot;
                    ctx.push(0, dup)?;
                    self.next_slot += interval;
                }
            }
        }
        self.next_slot = (buf.pts_ns - buf.pts_ns % interval) + interval;
        self.last = Some(buf.clone());
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};
    use crate::tensor::DType;

    #[test]
    fn drops_to_target_rate() {
        let mut r = TensorRate::new();
        r.set_property("framerate", "15").unwrap();
        let caps = Caps::tensor(DType::F32, [1], 30.0);
        let out_caps = r.negotiate(&[caps], 1).unwrap();
        assert_eq!(out_caps[0].fps(), Some(15.0));
        let (mut ctx, rxs) = ctx_with_outputs(1);
        // 30 fps input: pts every 33.3 ms for 1 second
        for i in 0..30u64 {
            let b = Buffer::from_f32(i * 33_333_333, &[i as f32]);
            r.handle(0, Item::Buffer(b), &mut ctx).unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        assert!(
            (13..=17).contains(&out.len()),
            "expected ~15 fps, got {}",
            out.len()
        );
    }

    #[test]
    fn passthrough_when_unset() {
        let mut r = TensorRate::new();
        let caps = Caps::tensor(DType::F32, [1], 30.0);
        r.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        for i in 0..5u64 {
            r.handle(0, Item::Buffer(Buffer::from_f32(i, &[0.0])), &mut ctx)
                .unwrap();
        }
        drop(ctx);
        assert_eq!(drain(&rxs[0]).len(), 5);
    }

    #[test]
    fn fills_gaps_when_throttling() {
        let mut r = TensorRate::new();
        r.set_property("framerate", "10").unwrap();
        r.set_property("throttle", "true").unwrap();
        let caps = Caps::tensor(DType::F32, [1], 2.0);
        r.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        // 2 fps input for 1s -> 10 fps output expects ~10 frames
        for i in 0..3u64 {
            let b = Buffer::from_f32(i * 500_000_000, &[i as f32]);
            r.handle(0, Item::Buffer(b), &mut ctx).unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        assert!(out.len() >= 9, "gap filling should emit ~10, got {}", out.len());
    }
}
