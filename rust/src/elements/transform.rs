//! `tensor_transform`: element-wise tensor operators (§III).
//!
//! Modes (NNStreamer-compatible property syntax):
//! * `mode=typecast option=float32` — dtype conversion
//! * `mode=arithmetic option=add:-127.5,div:127.5` — chained scalar ops
//! * `mode=normalize` — scale u8 [0,255] to f32 [0,1]
//! * `mode=transpose option=1:0:2:3` — axis permutation
//! * `mode=stand` — standardization (zero mean, unit variance per frame)
//!
//! The builder path skips the string syntax entirely:
//! [`TensorTransformProps::arithmetic`] & friends carry the already-typed
//! [`TransformMode`].

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, ChunkPool, DType, Dims, TensorInfo};

/// A typed transform operation.
#[derive(Debug, Clone, PartialEq)]
pub enum TransformMode {
    Typecast(DType),
    /// Chained scalar arithmetic, applied in order.
    Arithmetic(Vec<(ArithOp, f64)>),
    Normalize,
    /// Axis permutation (minor-first axis indices).
    Transpose(Vec<usize>),
    Stand,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// Typed properties of [`TensorTransform`].
///
/// Builder users construct through the typed helpers
/// ([`typecast`](TensorTransformProps::typecast),
/// [`arithmetic`](TensorTransformProps::arithmetic), ...); the string
/// front-end fills `mode`/`option` text that resolves to the same
/// [`TransformMode`] at negotiation time (the option may legally arrive
/// before the mode in a launch string, hence the deferred resolution).
#[derive(Debug, Clone, Default)]
pub struct TensorTransformProps {
    /// Typed mode; `None` means passthrough unless the string fields
    /// below resolve to something.
    pub mode: Option<TransformMode>,
    mode_str: String,
    option_str: String,
}

impl TensorTransformProps {
    pub fn typed(mode: TransformMode) -> Self {
        Self {
            mode: Some(mode),
            ..Default::default()
        }
    }

    pub fn typecast(dtype: DType) -> Self {
        Self::typed(TransformMode::Typecast(dtype))
    }

    pub fn arithmetic(ops: Vec<(ArithOp, f64)>) -> Self {
        Self::typed(TransformMode::Arithmetic(ops))
    }

    pub fn normalize() -> Self {
        Self::typed(TransformMode::Normalize)
    }

    pub fn transpose(axes: Vec<usize>) -> Self {
        Self::typed(TransformMode::Transpose(axes))
    }

    pub fn stand() -> Self {
        Self::typed(TransformMode::Stand)
    }

    /// Resolve to the effective mode: the typed field wins, otherwise the
    /// string pair is parsed (`None` = passthrough).
    fn resolve(&self) -> Result<Option<TransformMode>> {
        if let Some(mode) = &self.mode {
            return Ok(Some(mode.clone()));
        }
        let mode = match self.mode_str.as_str() {
            "" | "passthrough" => None,
            "typecast" => Some(TransformMode::Typecast(DType::parse(&self.option_str)?)),
            "arithmetic" => {
                let mut ops = Vec::new();
                for part in self.option_str.split(',') {
                    let (op, v) = part.split_once(':').ok_or_else(|| {
                        Error::Parse(format!(
                            "arithmetic option must be op:value, got {part:?}"
                        ))
                    })?;
                    let value: f64 = v
                        .parse()
                        .map_err(|_| Error::Parse(format!("bad arithmetic value {v:?}")))?;
                    let op = match op {
                        "add" => ArithOp::Add,
                        "sub" => ArithOp::Sub,
                        "mul" | "mult" => ArithOp::Mul,
                        "div" => ArithOp::Div,
                        _ => return Err(Error::Parse(format!("bad arithmetic op {op:?}"))),
                    };
                    ops.push((op, value));
                }
                Some(TransformMode::Arithmetic(ops))
            }
            "normalize" => Some(TransformMode::Normalize),
            "transpose" => {
                let axes: Vec<usize> = self
                    .option_str
                    .split(':')
                    .map(|a| {
                        a.parse()
                            .map_err(|_| Error::Parse(format!("bad transpose axis {a:?}")))
                    })
                    .collect::<Result<_>>()?;
                Some(TransformMode::Transpose(axes))
            }
            "stand" => Some(TransformMode::Stand),
            other => return Err(Error::Parse(format!("unknown transform mode {other:?}"))),
        };
        Ok(mode)
    }
}

impl Props for TensorTransformProps {
    const FACTORY: &'static str = "tensor_transform";
    const KEYS: &'static [&'static str] = &["mode", "option"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "mode" => {
                // validate the mode name eagerly; option parsing happens at
                // negotiate time (option may not be set yet)
                if !matches!(
                    value,
                    "" | "passthrough"
                        | "typecast"
                        | "arithmetic"
                        | "normalize"
                        | "transpose"
                        | "stand"
                ) {
                    return Err(Error::Parse(format!("unknown transform mode {value:?}")));
                }
                self.mode_str = value.to_string();
                // a string-mode reconfiguration overrides an earlier
                // typed mode
                self.mode = None;
            }
            "option" => {
                // an option alone cannot reconfigure a typed mode — the
                // string pair resolves through mode_str, which only a
                // mode= assignment establishes
                if self.mode.is_some() && self.mode_str.is_empty() {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "transform has a typed mode; set mode= first to \
                                 reconfigure via string properties"
                            .into(),
                    });
                }
                self.option_str = value.to_string();
            }
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorTransform::from_props(self)?))
    }
}

pub struct TensorTransform {
    props: TensorTransformProps,
    mode: Option<TransformMode>,
    in_info: Option<TensorInfo>,
    out_info: Option<TensorInfo>,
}

impl TensorTransform {
    pub fn new() -> Self {
        Self::from_props(TensorTransformProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorTransform {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorTransform {
    type Props = TensorTransformProps;

    fn from_props(props: TensorTransformProps) -> Result<Self> {
        Ok(Self {
            props,
            mode: None,
            in_info: None,
            out_info: None,
        })
    }
}

/// Read one element of any supported dtype as f64.
#[inline]
fn read_elem_f64(e: &[u8], dtype: DType) -> f64 {
    match dtype {
        DType::U8 => e[0] as f64,
        DType::I8 => e[0] as i8 as f64,
        DType::U16 => u16::from_le_bytes([e[0], e[1]]) as f64,
        DType::I16 => i16::from_le_bytes([e[0], e[1]]) as f64,
        DType::U32 => u32::from_le_bytes([e[0], e[1], e[2], e[3]]) as f64,
        DType::I32 => i32::from_le_bytes([e[0], e[1], e[2], e[3]]) as f64,
        DType::U64 => u64::from_le_bytes(e[..8].try_into().unwrap()) as f64,
        DType::I64 => i64::from_le_bytes(e[..8].try_into().unwrap()) as f64,
        DType::F32 => f32::from_le_bytes([e[0], e[1], e[2], e[3]]) as f64,
        DType::F64 => f64::from_le_bytes(e[..8].try_into().unwrap()),
    }
}

/// Write one f64 value as the requested dtype (saturating integer casts).
#[inline]
fn write_elem_f64(v: f64, dtype: DType, out: &mut [u8]) {
    match dtype {
        DType::U8 => out[0] = v.clamp(0.0, 255.0) as u8,
        DType::I8 => out[0] = v.clamp(-128.0, 127.0) as i8 as u8,
        DType::U16 => out[..2].copy_from_slice(&(v.clamp(0.0, 65535.0) as u16).to_le_bytes()),
        DType::I16 => {
            out[..2].copy_from_slice(&(v.clamp(-32768.0, 32767.0) as i16).to_le_bytes())
        }
        DType::U32 => out[..4].copy_from_slice(&(v.max(0.0) as u32).to_le_bytes()),
        DType::I32 => out[..4].copy_from_slice(&(v as i32).to_le_bytes()),
        DType::U64 => out[..8].copy_from_slice(&(v.max(0.0) as u64).to_le_bytes()),
        DType::I64 => out[..8].copy_from_slice(&(v as i64).to_le_bytes()),
        DType::F32 => out[..4].copy_from_slice(&(v as f32).to_le_bytes()),
        DType::F64 => out[..8].copy_from_slice(&v.to_le_bytes()),
    }
}

/// Read any supported dtype as f64 for arithmetic.
fn read_as_f64(data: &[u8], dtype: DType) -> Vec<f64> {
    data.chunks_exact(dtype.size_bytes())
        .map(|e| read_elem_f64(e, dtype))
        .collect()
}

impl Element for TensorTransform {
    fn type_name(&self) -> &'static str {
        "tensor_transform"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        self.mode = self.props.resolve()?;
        let (info, fps) = match &in_caps[0] {
            Caps::Tensor { info, fps_millis } => (info.clone(), *fps_millis),
            other => {
                return Err(Error::Negotiation(format!(
                    "tensor_transform needs other/tensor input, got {other}"
                )))
            }
        };
        self.in_info = Some(info.clone());
        let out_info = match &self.mode {
            Some(TransformMode::Typecast(t)) => TensorInfo::new(*t, info.dims.clone()),
            Some(TransformMode::Normalize) | Some(TransformMode::Stand) => {
                TensorInfo::new(DType::F32, info.dims.clone())
            }
            Some(TransformMode::Transpose(axes)) => {
                let in_dims = info.dims.as_slice();
                if axes.len() < in_dims.len() {
                    return Err(Error::Negotiation(format!(
                        "transpose axes {axes:?} shorter than rank {}",
                        in_dims.len()
                    )));
                }
                let mut dims = Vec::new();
                for &a in axes.iter().take(in_dims.len().max(axes.len())) {
                    dims.push(if a < in_dims.len() { in_dims[a] } else { 1 });
                }
                TensorInfo::new(info.dtype, Dims::new(&dims[..in_dims.len()]))
            }
            Some(TransformMode::Arithmetic(_)) | None => info.clone(),
        };
        self.out_info = Some(out_info.clone());
        Ok(vec![
            Caps::Tensor {
                info: out_info,
                fps_millis: fps
            };
            n_srcs.max(1)
        ])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(mut buf) = item else {
            return Ok(Flow::Continue);
        };
        let in_info = self
            .in_info
            .as_ref()
            .ok_or_else(|| Error::element("tensor_transform", "not negotiated"))?;
        let out_info = self.out_info.clone().unwrap();

        let out_chunk = match &self.mode {
            // passthrough moves the chunk (keeps it uniquely owned for
            // downstream in-place stages)
            None => buf.chunks.swap_remove(0),
            // fast path: u8 -> f32 (the dominant video-pipeline cast),
            // streamed straight into pooled storage
            Some(TransformMode::Typecast(DType::F32)) if in_info.dtype == DType::U8 => {
                let src = buf.chunk().as_bytes();
                Chunk::from_f32_iter(src.len(), src.iter().map(|&v| v as f32))
            }
            Some(TransformMode::Typecast(t)) => {
                let t = *t;
                let src = buf.chunk().as_bytes();
                let esz_in = in_info.dtype.size_bytes();
                let n = src.len() / esz_in;
                let mut out = ChunkPool::global().take(n * t.size_bytes());
                for (e, dst) in src
                    .chunks_exact(esz_in)
                    .zip(out.chunks_exact_mut(t.size_bytes()))
                {
                    write_elem_f64(read_elem_f64(e, in_info.dtype), t, dst);
                }
                Chunk::from_pooled(out)
            }
            Some(TransformMode::Normalize) if in_info.dtype == DType::U8 => {
                let src = buf.chunk().as_bytes();
                Chunk::from_f32_iter(src.len(), src.iter().map(|&v| v as f32 / 255.0))
            }
            Some(TransformMode::Normalize) => {
                let vals = read_as_f64(buf.chunk().as_bytes(), in_info.dtype);
                Chunk::from_f32_iter(vals.len(), vals.iter().map(|v| (*v / 255.0) as f32))
            }
            // f32 standardization runs in place (CoW when the chunk is
            // shared, e.g. behind a tee)
            Some(TransformMode::Stand) if in_info.dtype == DType::F32 => {
                let mut chunk = buf.chunks.swap_remove(0);
                {
                    let vals = chunk.make_mut_f32()?;
                    let n = vals.len().max(1) as f32;
                    let mean = vals.iter().sum::<f32>() / n;
                    let var =
                        vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
                    let sd = var.sqrt().max(1e-10);
                    for v in vals.iter_mut() {
                        *v = (*v - mean) / sd;
                    }
                }
                chunk
            }
            Some(TransformMode::Stand) => {
                let vals = read_as_f64(buf.chunk().as_bytes(), in_info.dtype);
                let n = vals.len().max(1) as f64;
                let mean = vals.iter().sum::<f64>() / n;
                let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
                let sd = var.sqrt().max(1e-10);
                Chunk::from_f32_iter(
                    vals.len(),
                    vals.iter().map(|v| ((*v - mean) / sd) as f32),
                )
            }
            // fast path: f32 arithmetic stays in f32 and runs in place
            Some(TransformMode::Arithmetic(ops)) if in_info.dtype == DType::F32 => {
                let mut chunk = buf.chunks.swap_remove(0);
                {
                    let vals = chunk.make_mut_f32()?;
                    for (op, c) in ops {
                        let c = *c as f32;
                        match op {
                            ArithOp::Add => vals.iter_mut().for_each(|v| *v += c),
                            ArithOp::Sub => vals.iter_mut().for_each(|v| *v -= c),
                            ArithOp::Mul => vals.iter_mut().for_each(|v| *v *= c),
                            ArithOp::Div => vals.iter_mut().for_each(|v| *v /= c),
                        }
                    }
                }
                chunk
            }
            // same-dtype element-wise arithmetic: through f64, in place
            Some(TransformMode::Arithmetic(ops)) => {
                let dtype = in_info.dtype;
                let mut chunk = buf.chunks.swap_remove(0);
                {
                    let bytes = chunk.make_mut();
                    for e in bytes.chunks_exact_mut(dtype.size_bytes()) {
                        let mut v = read_elem_f64(e, dtype);
                        for (op, c) in ops {
                            match op {
                                ArithOp::Add => v += c,
                                ArithOp::Sub => v -= c,
                                ArithOp::Mul => v *= c,
                                ArithOp::Div => v /= c,
                            }
                        }
                        write_elem_f64(v, dtype, e);
                    }
                }
                chunk
            }
            Some(TransformMode::Transpose(axes)) => {
                let esz = in_info.dtype.size_bytes();
                let in_dims = in_info.dims.as_slice();
                let rank = in_dims.len();
                let data = buf.chunk().as_bytes();
                // strides of input, in elements (NNStreamer dims are
                // minor-first: dim 0 is the fastest-varying)
                let mut strides = vec![1usize; rank];
                for i in 1..rank {
                    strides[i] = strides[i - 1] * in_dims[i - 1];
                }
                let out_dims = out_info.dims.as_slice().to_vec();
                let total: usize = out_dims.iter().product();
                let mut out = ChunkPool::global().take(total * esz);
                let mut idx = vec![0usize; rank];
                for lin in 0..total {
                    // decompose lin into out coords (minor-first)
                    let mut rem = lin;
                    for (i, &d) in out_dims.iter().enumerate() {
                        idx[i] = rem % d;
                        rem /= d;
                    }
                    // out coord i corresponds to in axis axes[i]
                    let mut src = 0usize;
                    for i in 0..rank {
                        src += idx[i] * strides[axes[i]];
                    }
                    out[lin * esz..(lin + 1) * esz]
                        .copy_from_slice(&data[src * esz..(src + 1) * esz]);
                }
                Chunk::from_pooled(out)
            }
        };
        let mut out = Buffer::single(buf.pts_ns, out_chunk);
        out.seq = buf.seq;
        out.duration_ns = buf.duration_ns;
        ctx.push(0, out)?;
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_transform(t: &mut TensorTransform, in_caps: Caps, data: Buffer) -> Buffer {
        t.negotiate(&[in_caps], 1).unwrap();
        // drive handle() with a captive ctx via a 1-element pipeline hack:
        // we call the internals directly through a scratch harness.
        harness(t, data)
    }

    /// Minimal direct-drive harness for a single element.
    fn harness(el: &mut dyn Element, buf: Buffer) -> Buffer {
        let (mut ctx, pads) = crate::element::testutil::ctx_with_outputs(1);
        el.handle(0, Item::Buffer(buf), &mut ctx).unwrap();
        drop(ctx);
        crate::element::testutil::drain(&pads[0])
            .into_iter()
            .next()
            .expect("no buffer")
    }

    #[test]
    fn typecast_u8_to_f32() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "typecast").unwrap();
        t.set_property("option", "float32").unwrap();
        let caps = Caps::tensor(DType::U8, [4], 0.0);
        let buf = Buffer::single(0, Chunk::from_vec(vec![0, 1, 128, 255]));
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[0.0, 1.0, 128.0, 255.0]);
    }

    #[test]
    fn arithmetic_chain() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "arithmetic").unwrap();
        t.set_property("option", "add:-127.5,div:127.5").unwrap();
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        let buf = Buffer::from_f32(0, &[0.0, 255.0]);
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[-1.0, 1.0]);
    }

    #[test]
    fn typed_mode_matches_string_mode() {
        // builder path: the typed props produce the same bytes as the
        // string front-end
        let mut a = TensorTransform::from_props(TensorTransformProps::arithmetic(vec![
            (ArithOp::Add, -127.5),
            (ArithOp::Div, 127.5),
        ]))
        .unwrap();
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        let buf = Buffer::from_f32(0, &[0.0, 255.0]);
        let out = run_transform(&mut a, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[-1.0, 1.0]);
    }

    #[test]
    fn normalize_scales() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "normalize").unwrap();
        let caps = Caps::tensor(DType::U8, [2], 0.0);
        let buf = Buffer::single(0, Chunk::from_vec(vec![0, 255]));
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[0.0, 1.0]);
    }

    #[test]
    fn stand_zero_mean() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "stand").unwrap();
        let caps = Caps::tensor(DType::F32, [4], 0.0);
        let buf = Buffer::from_f32(0, &[1.0, 2.0, 3.0, 4.0]);
        let out = run_transform(&mut t, caps, buf);
        let vals = out.chunk().to_f32_vec().unwrap();
        let mean: f32 = vals.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
    }

    #[test]
    fn transpose_2d() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "transpose").unwrap();
        t.set_property("option", "1:0").unwrap();
        // dims 2:3 (minor-first: 2 columns, 3 rows) values row-major by dim1
        let caps = Caps::tensor(DType::F32, [2, 3], 0.0);
        let buf = Buffer::from_f32(0, &[1., 2., 3., 4., 5., 6.]);
        let out = run_transform(&mut t, caps, buf);
        // transposed to 3:2
        assert_eq!(out.chunk().as_f32().unwrap(), &[1., 3., 5., 2., 4., 6.]);
    }

    #[test]
    fn f32_arithmetic_runs_in_place_when_unshared() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "arithmetic").unwrap();
        t.set_property("option", "add:1").unwrap();
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        let buf = Buffer::from_f32(0, &[1.0, 2.0]);
        let p = buf.chunk().ptr();
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[2.0, 3.0]);
        assert_eq!(out.chunk().ptr(), p, "unique input must mutate in place");
    }

    #[test]
    fn f32_arithmetic_copies_when_input_is_shared() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "arithmetic").unwrap();
        t.set_property("option", "add:1").unwrap();
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        let buf = Buffer::from_f32(0, &[1.0, 2.0]);
        let upstream = buf.clone(); // e.g. a tee branch holding the chunk
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_f32().unwrap(), &[2.0, 3.0]);
        assert_eq!(
            upstream.chunk().as_f32().unwrap(),
            &[1.0, 2.0],
            "CoW must not mutate the shared sibling"
        );
        assert_ne!(out.chunk().ptr(), upstream.chunk().ptr());
    }

    #[test]
    fn u8_arithmetic_saturates_like_the_vec_path() {
        let mut t = TensorTransform::new();
        t.set_property("mode", "arithmetic").unwrap();
        t.set_property("option", "add:200").unwrap();
        let caps = Caps::tensor(DType::U8, [3], 0.0);
        let buf = Buffer::single(0, Chunk::from_vec(vec![0, 100, 255]));
        let out = run_transform(&mut t, caps, buf);
        assert_eq!(out.chunk().as_bytes_unaccounted(), &[200, 255, 255]);
    }

    #[test]
    fn rejects_bad_mode() {
        let mut t = TensorTransform::new();
        assert!(t.set_property("mode", "frobnicate").is_err());
    }
}
