//! `tensor_if`: value-predicated flow control (§III).
//!
//! Routes buffers based on tensor values *without application-thread
//! intervention*: compare a computed value (average/max/element) against a
//! threshold and either pass/drop or route to the then/else src pad.
//!
//! Properties (NNStreamer-flavored):
//! * `compared-value=average|max|element:<idx>`
//! * `operator=gt|ge|lt|le|eq`
//! * `threshold=<float>`
//! * `action=pass|route` — `pass`: forward on pad 0 when true else drop;
//!   `route`: pad 0 when true, pad 1 when false.

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, DType, TensorInfo};

use super::sources::parse_f64;

/// What [`TensorIf`] computes from each buffer (`compared-value`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComparedValue {
    Average,
    Max,
    Element(usize),
}

impl ComparedValue {
    pub fn parse(value: &str) -> Result<Self> {
        if value == "average" {
            Ok(ComparedValue::Average)
        } else if value == "max" {
            Ok(ComparedValue::Max)
        } else if let Some(i) = value.strip_prefix("element:") {
            Ok(ComparedValue::Element(i.parse().map_err(|_| {
                Error::Property {
                    key: "compared-value".into(),
                    value: value.into(),
                    reason: "bad element index".into(),
                }
            })?))
        } else {
            Err(Error::Property {
                key: "compared-value".into(),
                value: value.into(),
                reason: "average|max|element:<idx>".into(),
            })
        }
    }
}

/// Comparison operator (`operator`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompareOp {
    Gt,
    Ge,
    Lt,
    Le,
    Eq,
}

impl CompareOp {
    pub fn parse(value: &str) -> Result<Self> {
        Ok(match value {
            "gt" => CompareOp::Gt,
            "ge" => CompareOp::Ge,
            "lt" => CompareOp::Lt,
            "le" => CompareOp::Le,
            "eq" => CompareOp::Eq,
            _ => {
                return Err(Error::Property {
                    key: "operator".into(),
                    value: value.into(),
                    reason: "gt|ge|lt|le|eq".into(),
                })
            }
        })
    }
}

/// What happens on a verdict (`action`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IfAction {
    /// Forward on pad 0 when true, drop otherwise.
    Pass,
    /// Pad 0 when true, pad 1 when false.
    Route,
}

impl IfAction {
    pub fn parse(value: &str) -> Result<Self> {
        Ok(match value {
            "pass" => IfAction::Pass,
            "route" => IfAction::Route,
            _ => {
                return Err(Error::Property {
                    key: "action".into(),
                    value: value.into(),
                    reason: "pass|route".into(),
                })
            }
        })
    }
}

/// Typed properties of [`TensorIf`]. The `threshold` is re-read for every
/// buffer, so it can be retuned on a playing pipeline through
/// [`Running::set_property`](crate::pipeline::Running::set_property).
#[derive(Debug, Clone, Copy)]
pub struct TensorIfProps {
    pub compared_value: ComparedValue,
    pub operator: CompareOp,
    pub threshold: f64,
    pub action: IfAction,
}

impl Default for TensorIfProps {
    fn default() -> Self {
        Self {
            compared_value: ComparedValue::Average,
            operator: CompareOp::Gt,
            threshold: 0.0,
            action: IfAction::Pass,
        }
    }
}

impl Props for TensorIfProps {
    const FACTORY: &'static str = "tensor_if";
    const KEYS: &'static [&'static str] =
        &["compared-value", "operator", "threshold", "action"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "compared-value" => self.compared_value = ComparedValue::parse(value)?,
            "operator" => self.operator = CompareOp::parse(value)?,
            "threshold" => self.threshold = parse_f64(key, value)?,
            "action" => self.action = IfAction::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorIf::from_props(self)?))
    }
}

pub struct TensorIf {
    props: TensorIfProps,
    in_info: Option<TensorInfo>,
}

impl FromProps for TensorIf {
    type Props = TensorIfProps;

    fn from_props(props: TensorIfProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
        })
    }
}

impl TensorIf {
    pub fn new() -> Self {
        Self::from_props(TensorIfProps::default()).expect("defaults are valid")
    }

    fn value_of(&self, buf: &Buffer, dtype: DType) -> Result<f64> {
        let data = buf.chunk().as_bytes();
        let esz = dtype.size_bytes();
        let n = data.len() / esz;
        let get = |i: usize| -> f64 {
            let o = i * esz;
            match dtype {
                DType::U8 => data[o] as f64,
                DType::I8 => data[o] as i8 as f64,
                DType::F32 => {
                    f32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::F64 => f64::from_le_bytes(data[o..o + 8].try_into().unwrap()),
                DType::I16 => i16::from_le_bytes([data[o], data[o + 1]]) as f64,
                DType::U16 => u16::from_le_bytes([data[o], data[o + 1]]) as f64,
                DType::I32 => {
                    i32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::U32 => {
                    u32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::I64 => i64::from_le_bytes(data[o..o + 8].try_into().unwrap()) as f64,
                DType::U64 => u64::from_le_bytes(data[o..o + 8].try_into().unwrap()) as f64,
            }
        };
        Ok(match self.props.compared_value {
            ComparedValue::Average => (0..n).map(get).sum::<f64>() / n.max(1) as f64,
            ComparedValue::Max => (0..n).map(get).fold(f64::MIN, f64::max),
            ComparedValue::Element(i) => {
                if i >= n {
                    return Err(Error::element(
                        "tensor_if",
                        format!("element index {i} out of range ({n} elements)"),
                    ));
                }
                get(i)
            }
        })
    }

    fn test(&self, v: f64) -> bool {
        let threshold = self.props.threshold;
        match self.props.operator {
            CompareOp::Gt => v > threshold,
            CompareOp::Ge => v >= threshold,
            CompareOp::Lt => v < threshold,
            CompareOp::Le => v <= threshold,
            CompareOp::Eq => (v - threshold).abs() < 1e-9,
        }
    }
}

impl Default for TensorIf {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorIf {
    fn type_name(&self) -> &'static str {
        "tensor_if"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 2 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Tensor { info, .. } = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "tensor_if needs other/tensor input, got {}",
                in_caps[0]
            )));
        };
        self.in_info = Some(info.clone());
        if self.props.action == IfAction::Route && n_srcs != 2 {
            return Err(Error::Negotiation(
                "tensor_if action=route needs exactly 2 src pads".into(),
            ));
        }
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let dtype = self.in_info.as_ref().unwrap().dtype;
        let v = self.value_of(&buf, dtype)?;
        let verdict = self.test(v);
        match (self.props.action, verdict) {
            (IfAction::Pass, true) => ctx.push(0, buf)?,
            (IfAction::Pass, false) => ctx.stats().record_drop(),
            (IfAction::Route, true) => ctx.push(0, buf)?,
            (IfAction::Route, false) => ctx.push(1, buf)?,
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};

    fn iff(props: &[(&str, &str)]) -> TensorIf {
        let mut t = TensorIf::new();
        for (k, v) in props {
            t.set_property(k, v).unwrap();
        }
        t
    }

    #[test]
    fn pass_drops_below_threshold() {
        let mut t = iff(&[
            ("compared-value", "average"),
            ("operator", "gt"),
            ("threshold", "0.5"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[0.9, 0.9])), &mut ctx)
            .unwrap();
        t.handle(0, Item::Buffer(Buffer::from_f32(1, &[0.1, 0.1])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].pts_ns, 0);
    }

    #[test]
    fn route_splits_by_predicate() {
        let mut t = iff(&[
            ("compared-value", "max"),
            ("operator", "ge"),
            ("threshold", "1.0"),
            ("action", "route"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 2).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(2);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[2.0, 0.0])), &mut ctx)
            .unwrap();
        t.handle(0, Item::Buffer(Buffer::from_f32(1, &[0.5, 0.2])), &mut ctx)
            .unwrap();
        drop(ctx);
        assert_eq!(drain(&rxs[0]).len(), 1);
        assert_eq!(drain(&rxs[1]).len(), 1);
    }

    #[test]
    fn element_selector() {
        let mut t = iff(&[
            ("compared-value", "element:1"),
            ("operator", "eq"),
            ("threshold", "7"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[0.0, 7.0])), &mut ctx)
            .unwrap();
        drop(ctx);
        assert_eq!(drain(&rxs[0]).len(), 1);
    }
}
