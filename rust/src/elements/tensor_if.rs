//! `tensor_if`: value-predicated flow control (§III).
//!
//! Routes buffers based on tensor values *without application-thread
//! intervention*: compare a computed value (average/max/element) against a
//! threshold and either pass/drop or route to the then/else src pad.
//!
//! Properties (NNStreamer-flavored):
//! * `compared-value=average|max|element:<idx>`
//! * `operator=gt|ge|lt|le|eq`
//! * `threshold=<float>`
//! * `action=pass|route` — `pass`: forward on pad 0 when true else drop;
//!   `route`: pad 0 when true, pad 1 when false.

use crate::element::{Ctx, Element, Flow, Item, PadSpec};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, DType, TensorInfo};

use super::sources::parse_f64;

#[derive(Debug, Clone, Copy, PartialEq)]
enum ComparedValue {
    Average,
    Max,
    Element(usize),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    Gt,
    Ge,
    Lt,
    Le,
    Eq,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Action {
    Pass,
    Route,
}

pub struct TensorIf {
    cv: ComparedValue,
    op: Op,
    threshold: f64,
    action: Action,
    in_info: Option<TensorInfo>,
}

impl TensorIf {
    pub fn new() -> Self {
        Self {
            cv: ComparedValue::Average,
            op: Op::Gt,
            threshold: 0.0,
            action: Action::Pass,
            in_info: None,
        }
    }

    fn value_of(&self, buf: &Buffer, dtype: DType) -> Result<f64> {
        let data = buf.chunk().as_bytes();
        let esz = dtype.size_bytes();
        let n = data.len() / esz;
        let get = |i: usize| -> f64 {
            let o = i * esz;
            match dtype {
                DType::U8 => data[o] as f64,
                DType::I8 => data[o] as i8 as f64,
                DType::F32 => {
                    f32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::F64 => f64::from_le_bytes(data[o..o + 8].try_into().unwrap()),
                DType::I16 => i16::from_le_bytes([data[o], data[o + 1]]) as f64,
                DType::U16 => u16::from_le_bytes([data[o], data[o + 1]]) as f64,
                DType::I32 => {
                    i32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::U32 => {
                    u32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]) as f64
                }
                DType::I64 => i64::from_le_bytes(data[o..o + 8].try_into().unwrap()) as f64,
                DType::U64 => u64::from_le_bytes(data[o..o + 8].try_into().unwrap()) as f64,
            }
        };
        Ok(match self.cv {
            ComparedValue::Average => (0..n).map(get).sum::<f64>() / n.max(1) as f64,
            ComparedValue::Max => (0..n).map(get).fold(f64::MIN, f64::max),
            ComparedValue::Element(i) => {
                if i >= n {
                    return Err(Error::element(
                        "tensor_if",
                        format!("element index {i} out of range ({n} elements)"),
                    ));
                }
                get(i)
            }
        })
    }

    fn test(&self, v: f64) -> bool {
        match self.op {
            Op::Gt => v > self.threshold,
            Op::Ge => v >= self.threshold,
            Op::Lt => v < self.threshold,
            Op::Le => v <= self.threshold,
            Op::Eq => (v - self.threshold).abs() < 1e-9,
        }
    }
}

impl Default for TensorIf {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorIf {
    fn type_name(&self) -> &'static str {
        "tensor_if"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 2 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "compared-value" => {
                self.cv = if value == "average" {
                    ComparedValue::Average
                } else if value == "max" {
                    ComparedValue::Max
                } else if let Some(i) = value.strip_prefix("element:") {
                    ComparedValue::Element(i.parse().map_err(|_| Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "bad element index".into(),
                    })?)
                } else {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "average|max|element:<idx>".into(),
                    });
                };
            }
            "operator" => {
                self.op = match value {
                    "gt" => Op::Gt,
                    "ge" => Op::Ge,
                    "lt" => Op::Lt,
                    "le" => Op::Le,
                    "eq" => Op::Eq,
                    _ => {
                        return Err(Error::Property {
                            key: key.into(),
                            value: value.into(),
                            reason: "gt|ge|lt|le|eq".into(),
                        })
                    }
                }
            }
            "threshold" => self.threshold = parse_f64(key, value)?,
            "action" => {
                self.action = match value {
                    "pass" => Action::Pass,
                    "route" => Action::Route,
                    _ => {
                        return Err(Error::Property {
                            key: key.into(),
                            value: value.into(),
                            reason: "pass|route".into(),
                        })
                    }
                }
            }
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of tensor_if".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Tensor { info, .. } = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "tensor_if needs other/tensor input, got {}",
                in_caps[0]
            )));
        };
        self.in_info = Some(info.clone());
        if self.action == Action::Route && n_srcs != 2 {
            return Err(Error::Negotiation(
                "tensor_if action=route needs exactly 2 src pads".into(),
            ));
        }
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let dtype = self.in_info.as_ref().unwrap().dtype;
        let v = self.value_of(&buf, dtype)?;
        let verdict = self.test(v);
        match (self.action, verdict) {
            (Action::Pass, true) => ctx.push(0, buf)?,
            (Action::Pass, false) => ctx.stats().record_drop(),
            (Action::Route, true) => ctx.push(0, buf)?,
            (Action::Route, false) => ctx.push(1, buf)?,
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};

    fn iff(props: &[(&str, &str)]) -> TensorIf {
        let mut t = TensorIf::new();
        for (k, v) in props {
            t.set_property(k, v).unwrap();
        }
        t
    }

    #[test]
    fn pass_drops_below_threshold() {
        let mut t = iff(&[
            ("compared-value", "average"),
            ("operator", "gt"),
            ("threshold", "0.5"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[0.9, 0.9])), &mut ctx)
            .unwrap();
        t.handle(0, Item::Buffer(Buffer::from_f32(1, &[0.1, 0.1])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].pts_ns, 0);
    }

    #[test]
    fn route_splits_by_predicate() {
        let mut t = iff(&[
            ("compared-value", "max"),
            ("operator", "ge"),
            ("threshold", "1.0"),
            ("action", "route"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 2).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(2);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[2.0, 0.0])), &mut ctx)
            .unwrap();
        t.handle(0, Item::Buffer(Buffer::from_f32(1, &[0.5, 0.2])), &mut ctx)
            .unwrap();
        drop(ctx);
        assert_eq!(drain(&rxs[0]).len(), 1);
        assert_eq!(drain(&rxs[1]).len(), 1);
    }

    #[test]
    fn element_selector() {
        let mut t = iff(&[
            ("compared-value", "element:1"),
            ("operator", "eq"),
            ("threshold", "7"),
        ]);
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        t.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        t.handle(0, Item::Buffer(Buffer::from_f32(0, &[0.0, 7.0])), &mut ctx)
            .unwrap();
        drop(ctx);
        assert_eq!(drain(&rxs[0]).len(), 1);
    }
}
