//! Sink elements: `fakesink`, `appsink`, `tensor_sink`, `filesink`.

use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::element::props::{parse_bool, unknown_property};
use crate::element::{
    BufferCallback, ControlMsg, Ctx, Element, Flow, FromProps, Item, PadSpec, Props,
};
use crate::error::{Error, Fault, Result};
use crate::pipeline::executor::SharedWaker;
use crate::pipeline::stream::{Endpoint, EpPop, EpPush, StreamEnd, DEFAULT_ENDPOINT_CAPACITY};
use crate::tensor::{Buffer, Caps};

use super::sources::parse_usize;

/// Typed properties of [`FakeSink`].
#[derive(Debug, Clone, Copy, Default)]
pub struct FakeSinkProps {
    /// Request pipeline stop after this many buffers (`num-buffers`).
    pub num_buffers: Option<u64>,
}

impl Props for FakeSinkProps {
    const FACTORY: &'static str = "fakesink";
    const KEYS: &'static [&'static str] = &["num-buffers"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "num-buffers" => self.num_buffers = Some(parse_usize(key, value)? as u64),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(FakeSink::from_props(self)?))
    }
}

/// Discards everything; optionally records end-to-end latency (pts vs
/// wall-clock against the pipeline epoch) for live pipelines.
pub struct FakeSink {
    props: FakeSinkProps,
    seen: u64,
    /// Sum/max of (arrival wall time − pts) for live latency reporting.
    lat_sum_ns: u64,
    lat_max_ns: u64,
}

impl FakeSink {
    pub fn new() -> Self {
        Self::from_props(FakeSinkProps::default()).expect("defaults are valid")
    }

    /// Mean end-to-end latency (only meaningful for live pipelines).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.lat_sum_ns as f64 / self.seen as f64 / 1e6
        }
    }

    pub fn max_latency_ms(&self) -> f64 {
        self.lat_max_ns as f64 / 1e6
    }

    pub fn count(&self) -> u64 {
        self.seen
    }
}

impl Default for FakeSink {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for FakeSink {
    type Props = FakeSinkProps;

    fn from_props(props: FakeSinkProps) -> Result<Self> {
        Ok(Self {
            props,
            seen: 0,
            lat_sum_ns: 0,
            lat_max_ns: 0,
        })
    }
}

impl Element for FakeSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "fakesink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        match item {
            Item::Buffer(buf) => {
                self.seen += 1;
                let arrival = Instant::now().duration_since(ctx.epoch).as_nanos() as u64;
                let lat = arrival.saturating_sub(buf.pts_ns);
                self.lat_sum_ns += lat;
                self.lat_max_ns = self.lat_max_ns.max(lat);
                if let Some(max) = self.props.num_buffers {
                    if self.seen >= max {
                        ctx.request_stop();
                        return Ok(Flow::Eos);
                    }
                }
                Ok(Flow::Continue)
            }
            Item::Eos => Ok(Flow::Continue),
        }
    }
}

/// Typed properties of [`AppSink`].
#[derive(Debug, Clone, Copy, Default)]
pub struct AppSinkProps {
    /// Drop instead of blocking when the application is slow (`drop`).
    pub drop: bool,
}

impl Props for AppSinkProps {
    const FACTORY: &'static str = "appsink";
    const KEYS: &'static [&'static str] = &["drop"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "drop" => self.drop = parse_bool(value),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(AppSink::from_props(self)?))
    }
}

/// Hands buffers to the application through a bounded endpoint — since
/// the stream-endpoint redesign, the same `Endpoint` primitive that
/// backs topic subscriptions (`pipeline/stream.rs`), here as an
/// anonymous local topic with the element as its only publisher. The
/// endpoint closes at end-of-stream, so an application drain loop
/// (`while let Ok(buf) = rx.recv()`) terminates when the pipeline does.
/// With `drop=false` (default) a full endpoint makes the sink **park** —
/// the undelivered frame is handed back to the scheduler and the task
/// sleeps (costing no pool worker) until the application's
/// [`AppSinkReceiver`] frees a slot, drops, or a pipeline stop is
/// requested. Set `drop=true` for fire-and-forget delivery instead.
pub struct AppSink {
    ep: Arc<Endpoint>,
    /// Wakes this sink's parked task when the application drains a slot.
    wake: Arc<SharedWaker>,
    /// The receiver handle was taken (it can only be taken once).
    receiver_taken: bool,
    /// The application dropped the receiver: stop consuming.
    closed: bool,
    props: AppSinkProps,
}

/// Receiving end of an [`AppSink`]: the bounded endpoint whose pops
/// unpark the sink task whenever the application frees a slot (and whose
/// drop closes the stream). Mirrors the `std::sync::mpsc::Receiver`
/// surface the seed exposed.
pub struct AppSinkReceiver {
    ep: Arc<Endpoint>,
}

impl AppSinkReceiver {
    /// Block until the next buffer; errors once the stream ended and the
    /// endpoint drained. The error is the typed close-reason: a clean
    /// pipeline end yields [`StreamEnd::Eos`], an upstream element dying
    /// mid-stream yields [`StreamEnd::Fault`] — so an application can
    /// never mistake a fault-truncated stream for a complete one.
    pub fn recv(&self) -> std::result::Result<Buffer, StreamEnd> {
        // every pop wakes a parked sink so it can deliver its pending frame
        self.ep
            .pop_blocking()
            .ok_or_else(|| self.ep.close_reason().unwrap_or(StreamEnd::Eos))
    }

    pub fn try_recv(&self) -> std::result::Result<Buffer, TryRecvError> {
        match self.ep.try_pop() {
            EpPop::Item(b) => Ok(b),
            EpPop::Empty => Err(TryRecvError::Empty),
            EpPop::End => Err(TryRecvError::Disconnected),
        }
    }

    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Buffer, RecvTimeoutError> {
        match self.ep.pop_timeout(timeout) {
            EpPop::Item(b) => Ok(b),
            EpPop::Empty => Err(RecvTimeoutError::Timeout),
            EpPop::End => Err(RecvTimeoutError::Disconnected),
        }
    }

    /// Drain iterator; terminates when the pipeline reaches end-of-stream.
    pub fn iter(&self) -> impl Iterator<Item = Buffer> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }

    /// Why the stream ended — `None` while it is still flowing. Useful
    /// after [`try_recv`](AppSinkReceiver::try_recv) /
    /// [`recv_timeout`](AppSinkReceiver::recv_timeout) reported
    /// `Disconnected` (those keep their std error types), or after an
    /// [`iter`](AppSinkReceiver::iter) drain, to check whether the
    /// collected output is complete or fault-truncated.
    pub fn close_reason(&self) -> Option<StreamEnd> {
        self.ep.close_reason()
    }
}

impl Drop for AppSinkReceiver {
    fn drop(&mut self) {
        // closing the endpoint wakes a parked sink so it observes the
        // gone receiver and unwinds instead of waiting forever
        self.ep.close();
    }
}

impl AppSink {
    pub fn new() -> Self {
        Self::from_props(AppSinkProps::default()).expect("defaults are valid")
    }

    /// Take the receiving end (call before `Pipeline::play`).
    pub fn take_receiver(&mut self) -> Option<AppSinkReceiver> {
        if self.receiver_taken {
            return None;
        }
        self.receiver_taken = true;
        Some(AppSinkReceiver {
            ep: self.ep.clone(),
        })
    }
}

impl Default for AppSink {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for AppSink {
    fn drop(&mut self) {
        // the producer is gone: let the receiver drain queued buffers,
        // then observe end-of-stream instead of blocking forever (the
        // endpoint analog of dropping the old mpsc sender — covers
        // pipelines that are torn down without ever reaching flush())
        self.ep.set_eos();
    }
}

impl FromProps for AppSink {
    type Props = AppSinkProps;

    fn from_props(props: AppSinkProps) -> Result<Self> {
        let ep = Endpoint::standalone(DEFAULT_ENDPOINT_CAPACITY);
        let wake = SharedWaker::new();
        // the element task is the endpoint's producer; pops wake it
        ep.add_producer_waker(&wake);
        Ok(Self {
            ep,
            wake,
            receiver_taken: false,
            closed: false,
            props,
        })
    }
}

impl Element for AppSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "appsink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        if self.closed {
            return Ok(Flow::Eos);
        }
        // publish the waker before probing the endpoint, so a racing
        // application recv() can never free a slot unobserved
        self.wake.set(ctx.waker());
        match self.ep.try_push(buf) {
            EpPush::Ok => Ok(Flow::Continue),
            EpPush::Closed(_) => {
                // application dropped the receiver: stop consuming
                self.closed = true;
                Ok(Flow::Eos)
            }
            EpPush::Full(b) => {
                if self.props.drop {
                    ctx.stats().record_drop();
                    Ok(Flow::Continue)
                } else if ctx.stopped() {
                    // teardown in progress: don't wait on the application
                    ctx.stats().record_drop();
                    Ok(Flow::Continue)
                } else {
                    // application hasn't drained: hand the frame back and
                    // park (no pool worker held) until the receiver frees
                    // a slot, drops, or the pipeline is stopped
                    ctx.push_back_input(pad, Item::Buffer(b));
                    Ok(Flow::Wait)
                }
            }
        }
    }

    fn flush(&mut self, _ctx: &mut Ctx) -> Result<()> {
        // end the app endpoint so application drain loops terminate
        // (queued buffers still drain before recv() errors)
        self.ep.set_eos();
        Ok(())
    }

    fn on_fault(&mut self, fault: &Fault) {
        // the stream died upstream (or this task itself is dying): end
        // the app endpoint with the fault as its close-reason so the
        // application's recv() reports the truncation, never a clean EOS
        self.ep.fail(fault);
    }
}

/// Typed properties of [`TensorSink`].
#[derive(Debug, Clone, Copy)]
pub struct TensorSinkProps {
    /// Keep at most this many buffers for post-run inspection
    /// (`max-kept`).
    pub max_kept: usize,
}

impl Default for TensorSinkProps {
    fn default() -> Self {
        Self { max_kept: 4096 }
    }
}

impl Props for TensorSinkProps {
    const FACTORY: &'static str = "tensor_sink";
    const KEYS: &'static [&'static str] = &["max-kept"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "max-kept" => self.max_kept = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorSink::from_props(self)?))
    }
}

/// Collects buffers in memory for post-run inspection, and invokes a
/// subscribed callback per buffer — the paper's Pipeline-API sink
/// callback. Subscribe on a playing pipeline with
/// [`Running::subscribe`](crate::pipeline::Running::subscribe); the
/// callback runs on the sink's thread and sees every buffer the sink
/// processes, bit-identical to what the pull-based
/// [`buffers`](TensorSink::buffers) path records (which additionally
/// caps retention at `max-kept`).
pub struct TensorSink {
    pub buffers: Vec<Buffer>,
    props: TensorSinkProps,
    seen: u64,
    callback: Option<BufferCallback>,
}

impl TensorSink {
    pub fn new() -> Self {
        Self::from_props(TensorSinkProps::default()).expect("defaults are valid")
    }

    pub fn count(&self) -> u64 {
        self.seen
    }

    /// Attach a per-buffer callback directly (pre-play path; on a playing
    /// pipeline use `Running::subscribe`).
    pub fn set_callback(&mut self, callback: BufferCallback) {
        self.callback = Some(callback);
    }
}

impl Default for TensorSink {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorSink {
    type Props = TensorSinkProps;

    fn from_props(props: TensorSinkProps) -> Result<Self> {
        Ok(Self {
            buffers: Vec::new(),
            props,
            seen: 0,
            callback: None,
        })
    }
}

impl Element for TensorSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "tensor_sink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn handle_control(&mut self, msg: ControlMsg) -> Result<()> {
        match msg {
            ControlMsg::Subscribe(cb) => {
                self.callback = Some(cb);
                Ok(())
            }
            ControlMsg::SetProperty { key, value } => self.set_property(&key, &value),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            self.seen += 1;
            if let Some(cb) = &mut self.callback {
                cb(&buf);
            }
            if self.buffers.len() < self.props.max_kept {
                self.buffers.push(buf);
            }
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`FileSink`].
#[derive(Debug, Clone, Default)]
pub struct FileSinkProps {
    /// Path to append payloads to (`location`).
    pub location: String,
}

impl Props for FileSinkProps {
    const FACTORY: &'static str = "filesink";
    const KEYS: &'static [&'static str] = &["location"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "location" => self.location = value.to_string(),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(FileSink::from_props(self)?))
    }
}

/// Appends payloads to a file.
pub struct FileSink {
    props: FileSinkProps,
    file: Option<std::fs::File>,
}

impl FileSink {
    pub fn new() -> Self {
        Self::from_props(FileSinkProps::default()).expect("defaults are valid")
    }
}

impl Default for FileSink {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for FileSink {
    type Props = FileSinkProps;

    fn from_props(props: FileSinkProps) -> Result<Self> {
        Ok(Self { props, file: None })
    }
}

impl Element for FileSink {
    fn type_name(&self) -> &'static str {
        "filesink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        if self.props.location.is_empty() {
            return Err(Error::Negotiation("filesink needs location=".into()));
        }
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        use std::io::Write;
        if let Item::Buffer(buf) = item {
            if self.file.is_none() {
                self.file = Some(std::fs::File::create(&self.props.location)?);
            }
            let f = self.file.as_mut().unwrap();
            for c in &buf.chunks {
                f.write_all(c.as_bytes())?;
            }
        }
        Ok(Flow::Continue)
    }
}
