//! Sink elements: `fakesink`, `appsink`, `tensor_sink`, `filesink`.

use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::time::Instant;

use crate::element::{Ctx, Element, Flow, Item, PadSpec};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps};

use super::sources::parse_usize;

/// Discards everything; optionally records end-to-end latency (pts vs
/// wall-clock against the pipeline epoch) for live pipelines.
pub struct FakeSink {
    num_buffers: Option<u64>,
    seen: u64,
    /// Sum/max of (arrival wall time − pts) for live latency reporting.
    lat_sum_ns: u64,
    lat_max_ns: u64,
}

impl FakeSink {
    pub fn new() -> Self {
        Self {
            num_buffers: None,
            seen: 0,
            lat_sum_ns: 0,
            lat_max_ns: 0,
        }
    }

    /// Mean end-to-end latency (only meaningful for live pipelines).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.lat_sum_ns as f64 / self.seen as f64 / 1e6
        }
    }

    pub fn max_latency_ms(&self) -> f64 {
        self.lat_max_ns as f64 / 1e6
    }

    pub fn count(&self) -> u64 {
        self.seen
    }
}

impl Default for FakeSink {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for FakeSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "fakesink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "num-buffers" => {
                self.num_buffers = Some(parse_usize(key, value)? as u64);
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of fakesink".into(),
            }),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        match item {
            Item::Buffer(buf) => {
                self.seen += 1;
                let arrival = Instant::now().duration_since(ctx.epoch).as_nanos() as u64;
                let lat = arrival.saturating_sub(buf.pts_ns);
                self.lat_sum_ns += lat;
                self.lat_max_ns = self.lat_max_ns.max(lat);
                if let Some(max) = self.num_buffers {
                    if self.seen >= max {
                        ctx.request_stop();
                        return Ok(Flow::Eos);
                    }
                }
                Ok(Flow::Continue)
            }
            Item::Eos => Ok(Flow::Continue),
        }
    }
}

/// Hands buffers to the application through a bounded channel.
pub struct AppSink {
    tx: SyncSender<Buffer>,
    rx: Option<Receiver<Buffer>>,
    /// Drop instead of blocking when the app is slow (`drop=true`).
    drop_on_full: bool,
}

impl AppSink {
    pub fn new() -> Self {
        let (tx, rx) = std::sync::mpsc::sync_channel(64);
        Self {
            tx,
            rx: Some(rx),
            drop_on_full: false,
        }
    }

    /// Take the receiving end (call before `Pipeline::play`).
    pub fn take_receiver(&mut self) -> Option<Receiver<Buffer>> {
        self.rx.take()
    }
}

impl Default for AppSink {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for AppSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "appsink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "drop" => {
                self.drop_on_full = value == "true" || value == "1";
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of appsink".into(),
            }),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let gone = if self.drop_on_full {
                match self.tx.try_send(buf) {
                    Ok(()) => false,
                    Err(TrySendError::Full(_)) => {
                        ctx.stats().record_drop();
                        false
                    }
                    Err(TrySendError::Disconnected(_)) => true,
                }
            } else {
                self.tx.send(buf).is_err()
            };
            if gone {
                // application dropped the receiver: stop consuming
                return Ok(Flow::Eos);
            }
        }
        Ok(Flow::Continue)
    }
}

/// Collects buffers in memory for post-run inspection (tests/benches).
pub struct TensorSink {
    pub buffers: Vec<Buffer>,
    max_kept: usize,
    seen: u64,
}

impl TensorSink {
    pub fn new() -> Self {
        Self {
            buffers: Vec::new(),
            max_kept: 4096,
            seen: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.seen
    }
}

impl Default for TensorSink {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorSink {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "tensor_sink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "max-kept" => {
                self.max_kept = parse_usize(key, value)?;
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of tensor_sink".into(),
            }),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            self.seen += 1;
            if self.buffers.len() < self.max_kept {
                self.buffers.push(buf);
            }
        }
        Ok(Flow::Continue)
    }
}

/// Appends payloads to a file.
pub struct FileSink {
    location: String,
    file: Option<std::fs::File>,
}

impl FileSink {
    pub fn new() -> Self {
        Self {
            location: String::new(),
            file: None,
        }
    }
}

impl Default for FileSink {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for FileSink {
    fn type_name(&self) -> &'static str {
        "filesink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "location" => {
                self.location = value.to_string();
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of filesink".into(),
            }),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        if self.location.is_empty() {
            return Err(Error::Negotiation("filesink needs location=".into()));
        }
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        use std::io::Write;
        if let Item::Buffer(buf) = item {
            if self.file.is_none() {
                self.file = Some(std::fs::File::create(&self.location)?);
            }
            let f = self.file.as_mut().unwrap();
            for c in &buf.chunks {
                f.write_all(c.as_bytes())?;
            }
        }
        Ok(Flow::Continue)
    }
}
