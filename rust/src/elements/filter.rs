//! `tensor_filter`: neural networks as pipeline filters — the paper's
//! central element.
//!
//! Properties:
//! * `framework=` `xla` | `custom` | `passthrough` (the sub-plugin)
//! * `model=` artifact name (xla) or registered function name (custom)
//! * `accelerator=` `cpu` (default) | `npu`
//! * `device-class=` `a` | `b` | `c` (E3's hardware classes; default c)
//! * `batch=` max frames executed as one stacked invocation (default 1)
//! * `latency-budget=` max milliseconds to wait for more frames while
//!   assembling a batch (default 0: drain only already-queued frames)
//!
//! ## Batched execution
//!
//! With `batch=N`, the filter aggregates up to `N` frames per invocation:
//! the frame delivered by the scheduler plus whatever is already queued on
//! its bounded input channel (waiting at most `latency-budget` ms for
//! stragglers), then executes them as **one dispatch** through the NNFW
//! sub-plugin and de-batches the results, re-attaching each frame's
//! original timestamp, sequence number and duration. Outputs are
//! bit-identical to unbatched execution; only the per-dispatch overhead is
//! amortized. A partial batch always executes — frames are never held
//! across `handle` calls, so EOS needs no flush and a slow source simply
//! degrades to `batch=1` behavior.
//!
//! Memory: input chunks are borrowed straight into the backend (no input
//! copy), the backend draws its per-output scratch from the chunk pool's
//! f32 classes, and results adopt that storage directly as chunks
//! (`Chunk::from_pooled_f32`) — zero copies, zero steady-state
//! allocations (see DESIGN.md "Memory model").
//!
//! Input caps must carry the same element count/type the model expects
//! (insert `tensor_transform mode=typecast` upstream as real NNStreamer
//! pipelines do); dims are checked element-count-wise with rank-agnostic
//! semantics.

use std::time::{Duration, Instant};

use crate::devices::DeviceClass;
use crate::element::{Ctx, Element, Flow, Item};
use crate::error::{Error, Result};
use crate::metrics::stats::Domain;
use crate::nnfw::{Accelerator, CustomNnfw, Nnfw, PassthroughNnfw, XlaNnfw};
use crate::tensor::{Buffer, Caps, Chunk, TensorInfo};

/// Upper bound on `batch=` (a saturated channel of huge stacked frames
/// would otherwise balloon memory).
pub const MAX_BATCH: usize = 64;

pub struct TensorFilter {
    framework: String,
    model_name: String,
    accelerator: Accelerator,
    class: DeviceClass,
    batch: usize,
    latency_budget: Duration,
    plugin: Option<Box<dyn Nnfw>>,
    out_fps: u64,
}

impl TensorFilter {
    pub fn new() -> Self {
        Self {
            framework: "xla".to_string(),
            model_name: String::new(),
            accelerator: Accelerator::Cpu,
            class: DeviceClass::Pc,
            batch: 1,
            latency_budget: Duration::ZERO,
            plugin: None,
            out_fps: 0,
        }
    }

    /// Drain up to `batch - 1` additional ready frames from the input
    /// channel into `frames`, honoring the latency budget. Anything that
    /// is not a pad-0 buffer (EOS in particular) is pushed back for the
    /// scheduler.
    fn gather_batch(&self, frames: &mut Vec<Buffer>, ctx: &mut Ctx) {
        let deadline = Instant::now() + self.latency_budget;
        while frames.len() < self.batch {
            match ctx.try_pull_input() {
                Some((0, Item::Buffer(b))) => frames.push(b),
                Some((pad, item)) => {
                    ctx.push_back_input(pad, item);
                    return;
                }
                None => {
                    let now = Instant::now();
                    if now >= deadline {
                        return;
                    }
                    match ctx.pull_input_timeout(deadline - now) {
                        Some((0, Item::Buffer(b))) => frames.push(b),
                        Some((pad, item)) => {
                            ctx.push_back_input(pad, item);
                            return;
                        }
                        None => return,
                    }
                }
            }
        }
    }

    fn load_plugin(&mut self, in_infos: &[TensorInfo]) -> Result<()> {
        let plugin: Box<dyn Nnfw> = match self.framework.as_str() {
            "xla" => Box::new(XlaNnfw::load(
                &self.model_name,
                self.accelerator,
                self.class,
            )?),
            "custom" => Box::new(CustomNnfw::load(&self.model_name)?),
            "passthrough" => Box::new(PassthroughNnfw {
                info: in_infos.to_vec(),
            }),
            other => {
                return Err(Error::Negotiation(format!(
                    "tensor_filter: unknown framework {other:?}"
                )))
            }
        };
        // validate input compatibility (element count + dtype per tensor)
        let expect = plugin.inputs();
        if expect.len() != in_infos.len() {
            return Err(Error::Negotiation(format!(
                "tensor_filter {}: model wants {} input tensors, caps carry {}",
                self.model_name,
                expect.len(),
                in_infos.len()
            )));
        }
        for (have, want) in in_infos.iter().zip(&expect) {
            if have.dtype != want.dtype {
                return Err(Error::Negotiation(format!(
                    "tensor_filter {}: input dtype {} != model {}",
                    self.model_name, have.dtype, want.dtype
                )));
            }
            if have.dims.num_elements() != want.dims.num_elements() {
                return Err(Error::Negotiation(format!(
                    "tensor_filter {}: input {} has {} elements, model wants {} ({})",
                    self.model_name,
                    have.dims,
                    have.dims.num_elements(),
                    want.dims.num_elements(),
                    want.dims
                )));
            }
        }
        self.plugin = Some(plugin);
        Ok(())
    }
}

impl Default for TensorFilter {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorFilter {
    fn type_name(&self) -> &'static str {
        "tensor_filter"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "framework" => self.framework = value.to_string(),
            "model" => self.model_name = value.to_string(),
            "accelerator" => self.accelerator = Accelerator::parse(value)?,
            "device-class" => self.class = DeviceClass::parse(value)?,
            "batch" => {
                let n: usize = value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected integer".into(),
                })?;
                if n == 0 || n > MAX_BATCH {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: format!("batch must be in 1..={MAX_BATCH}"),
                    });
                }
                self.batch = n;
            }
            "latency-budget" => {
                let ms: f64 = value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected milliseconds".into(),
                })?;
                if !(ms >= 0.0) {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "latency budget must be >= 0".into(),
                    });
                }
                self.latency_budget = Duration::from_secs_f64(ms / 1e3);
            }
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of tensor_filter".into(),
                })
            }
        }
        Ok(())
    }

    /// A batching filter needs channel headroom to aggregate from.
    fn preferred_input_capacity(&self) -> usize {
        if self.batch > 1 {
            self.batch * 2
        } else {
            1
        }
    }

    fn domain(&self) -> Domain {
        if self.accelerator == Accelerator::Npu {
            Domain::Npu
        } else {
            Domain::Cpu
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let (in_infos, fps) = match &in_caps[0] {
            Caps::Tensor { info, fps_millis } => (vec![info.clone()], *fps_millis),
            Caps::Tensors { infos, fps_millis } => (infos.clone(), *fps_millis),
            other => {
                return Err(Error::Negotiation(format!(
                    "tensor_filter needs tensor input, got {other}"
                )))
            }
        };
        self.load_plugin(&in_infos)?;
        self.out_fps = fps;
        let outs = self.plugin.as_ref().unwrap().outputs();
        let caps = if outs.len() == 1 {
            Caps::Tensor {
                info: outs[0].clone(),
                fps_millis: fps,
            }
        } else {
            Caps::Tensors {
                infos: outs,
                fps_millis: fps,
            }
        };
        Ok(vec![caps; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let plugin = self
            .plugin
            .as_ref()
            .ok_or_else(|| Error::element("tensor_filter", "not negotiated"))?;
        let mut frames = Vec::with_capacity(self.batch);
        frames.push(buf);
        if self.batch > 1 {
            self.gather_batch(&mut frames, ctx);
        }
        let chunk_refs: Vec<Vec<&Chunk>> = frames
            .iter()
            .map(|b| b.chunks.iter().collect())
            .collect();
        let frame_refs: Vec<&[&Chunk]> =
            chunk_refs.iter().map(|v| v.as_slice()).collect();
        let outs = plugin.invoke_batch(&frame_refs).map_err(|e| {
            Error::element(
                format!("tensor_filter({})", self.model_name),
                e.to_string(),
            )
        })?;
        if outs.len() != frames.len() {
            return Err(Error::element(
                format!("tensor_filter({})", self.model_name),
                format!("batch of {} produced {} results", frames.len(), outs.len()),
            ));
        }
        // De-batch: each result keeps its frame's timestamp and ordering.
        for (frame, chunks) in frames.iter().zip(outs) {
            let mut out = Buffer::new(frame.pts_ns, chunks);
            out.seq = frame.seq;
            out.duration_ns = frame.duration_ns;
            ctx.push(0, out)?;
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};
    use crate::tensor::{Chunk, DType};

    #[test]
    fn passthrough_filter() {
        let mut f = TensorFilter::new();
        f.set_property("framework", "passthrough").unwrap();
        let caps = Caps::tensor(DType::F32, [4], 30.0);
        let out_caps = f.negotiate(&[caps.clone()], 1).unwrap();
        assert!(out_caps[0].compatible(&caps));
        let (mut ctx, rxs) = ctx_with_outputs(1);
        f.handle(0, Item::Buffer(Buffer::from_f32(7, &[1., 2., 3., 4.])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out[0].pts_ns, 7);
        assert_eq!(out[0].chunk().as_f32().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn xla_filter_end_to_end() {
        let mut f = TensorFilter::new();
        f.set_property("framework", "xla").unwrap();
        f.set_property("model", "ars_a_opt").unwrap();
        // ars_a: (1,128,3) f32 -> minor-first stream dims 3:128:1
        let caps = Caps::tensor(DType::F32, [3, 128, 1], 10.0);
        let out_caps = f.negotiate(&[caps], 1).unwrap();
        match &out_caps[0] {
            Caps::Tensor { info, .. } => {
                assert_eq!(info.dims.num_elements(), 8);
            }
            other => panic!("unexpected {other:?}"),
        }
        let (mut ctx, rxs) = ctx_with_outputs(1);
        let input = Buffer::single(0, Chunk::from_f32(&vec![0.3f32; 128 * 3]));
        f.handle(0, Item::Buffer(input), &mut ctx).unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        let probs = out[0].chunk().to_f32_vec().unwrap();
        assert_eq!(probs.len(), 8);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn batch_property_validated() {
        let mut f = TensorFilter::new();
        f.set_property("batch", "4").unwrap();
        assert_eq!(f.preferred_input_capacity(), 8);
        f.set_property("latency-budget", "2.5").unwrap();
        assert!(f.set_property("batch", "0").is_err());
        assert!(f
            .set_property("batch", &(MAX_BATCH + 1).to_string())
            .is_err());
        assert!(f.set_property("batch", "x").is_err());
        assert!(f.set_property("latency-budget", "-1").is_err());
    }

    #[test]
    fn batched_filter_without_queued_input_runs_partial_batches() {
        // the testutil ctx has no input channel: every handle() call is a
        // batch of one, and must still produce one output per input
        let mut f = TensorFilter::new();
        f.set_property("framework", "passthrough").unwrap();
        f.set_property("batch", "4").unwrap();
        let caps = Caps::tensor(DType::F32, [3], 30.0);
        f.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        for i in 0..3u64 {
            let buf = Buffer::from_f32(i * 100, &[i as f32, 1.0, 2.0]);
            f.handle(0, Item::Buffer(buf), &mut ctx).unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out.len(), 3);
        for (i, b) in out.iter().enumerate() {
            assert_eq!(b.pts_ns, i as u64 * 100);
            assert_eq!(b.chunk().as_f32().unwrap()[0], i as f32);
        }
    }

    #[test]
    fn rejects_wrong_input_size() {
        let mut f = TensorFilter::new();
        f.set_property("model", "ars_a_opt").unwrap();
        let caps = Caps::tensor(DType::F32, [7], 10.0);
        assert!(f.negotiate(&[caps], 1).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let mut f = TensorFilter::new();
        f.set_property("model", "ars_a_opt").unwrap();
        let caps = Caps::tensor(DType::U8, [3, 128, 1], 10.0);
        assert!(f.negotiate(&[caps], 1).is_err());
    }
}
