//! `tensor_filter`: neural networks as pipeline filters — the paper's
//! central element.
//!
//! Properties:
//! * `framework=` `xla` | `custom` | `passthrough`, or any sub-plugin
//!   name registered at runtime with [`crate::nnfw::register_nnfw`]
//!   (unknown names fail with a nearest-name suggestion)
//! * `model=` artifact name (xla) or registered function name (custom)
//! * `accelerator=` `cpu` (default) | `npu`
//! * `device-class=` `a` | `b` | `c` (E3's hardware classes; default c)
//! * `batch=` max frames executed as one stacked invocation (default 1)
//! * `latency-budget=` max milliseconds to wait for more frames while
//!   assembling a batch (default 0: drain only already-queued frames)
//! * `dispatch=` `async` (default) | `block` — whether modeled device
//!   time parks the filter on the executor's device lane (submit, stash,
//!   `Flow::Wait`, resume on completion — zero workers held) or blocks
//!   in-step like a synchronous driver call. `async` needs a pooled
//!   executor waker and silently degrades to blocking without one
//!   (testutil contexts, bare threads).
//!
//! ## Batched execution
//!
//! With `batch=N`, the filter aggregates up to `N` frames per invocation:
//! the frame delivered by the scheduler plus whatever is already queued on
//! its bounded input channel (waiting at most `latency-budget` ms for
//! stragglers), then executes them as **one dispatch** through the NNFW
//! sub-plugin and de-batches the results, re-attaching each frame's
//! original timestamp, sequence number and duration. Outputs are
//! bit-identical to unbatched execution; only the per-dispatch overhead is
//! amortized. A partial batch always executes — frames are never held
//! across `handle` calls, so EOS needs no flush and a slow source simply
//! degrades to `batch=1` behavior.
//!
//! Memory: input chunks are borrowed straight into the backend (no input
//! copy), the backend draws its per-output scratch from the chunk pool's
//! f32 classes, and results adopt that storage directly as chunks
//! (`Chunk::from_pooled_f32`) — zero copies, zero steady-state
//! allocations (see DESIGN.md "Memory model").
//!
//! Input caps must carry the same element count/type the model expects
//! (insert `tensor_transform mode=typecast` upstream as real NNStreamer
//! pipelines do); dims are checked element-count-wise with rank-agnostic
//! semantics.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::devices::{Completion, DeviceClass};
use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::metrics::stats::Domain;
use crate::nnfw::{
    Accelerator, AsyncInvoke, CustomNnfw, Nnfw, PassthroughNnfw, XlaNnfw,
};
use crate::pipeline::executor::SharedWaker;
use crate::tensor::{Buffer, Caps, Chunk, TensorInfo};

/// Upper bound on `batch=` (a saturated channel of huge stacked frames
/// would otherwise balloon memory).
pub const MAX_BATCH: usize = 64;

/// NNFW sub-plugin family executing a [`TensorFilter`].
///
/// The built-in set is open-ended: any name registered with
/// [`crate::nnfw::register_nnfw`] resolves to [`Framework::Plugin`], so
/// `framework=` dispatch extends at runtime exactly like the element
/// registry — the paper's extensible sub-plugin API.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum Framework {
    /// AOT-compiled artifacts through the shared model pool.
    #[default]
    Xla,
    /// A function registered with [`crate::nnfw::register_custom`].
    Custom,
    /// Identity (testing).
    Passthrough,
    /// A runtime-registered sub-plugin factory
    /// ([`crate::nnfw::register_nnfw`]).
    Plugin(String),
}

impl Framework {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "xla" => Framework::Xla,
            "custom" => Framework::Custom,
            "passthrough" => Framework::Passthrough,
            other => {
                if crate::nnfw::nnfw_exists(other) {
                    return Ok(Framework::Plugin(other.to_string()));
                }
                // nearest-name suggestion across built-ins and every
                // registered sub-plugin
                let registered = crate::nnfw::nnfw_names();
                let candidates = ["xla", "custom", "passthrough"]
                    .into_iter()
                    .chain(registered.iter().map(String::as_str));
                return Err(Error::Property {
                    key: "framework".into(),
                    value: other.into(),
                    reason: format!(
                        "not a built-in (xla|custom|passthrough) or registered \
                         NNFW sub-plugin{}",
                        crate::element::registry::did_you_mean(other, candidates)
                    ),
                });
            }
        })
    }
}

/// How modeled device/envelope time is waited out (`dispatch=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Submit and park on the executor's device lane: in-flight jobs hold
    /// zero pool workers.
    #[default]
    Async,
    /// Block inside the step for the full modeled service time (the
    /// synchronous driver-call shape; baseline for the e12 bench).
    Block,
}

impl DispatchMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "async" => DispatchMode::Async,
            "block" => DispatchMode::Block,
            other => {
                return Err(Error::Property {
                    key: "dispatch".into(),
                    value: other.into(),
                    reason: "expected async|block".into(),
                })
            }
        })
    }
}

/// Typed properties of [`TensorFilter`].
#[derive(Debug, Clone)]
pub struct TensorFilterProps {
    /// Sub-plugin family (`framework`).
    pub framework: Framework,
    /// Artifact name (xla) or registered function name (custom)
    /// (`model`).
    pub model: String,
    /// Execution device (`accelerator=cpu|npu`).
    pub accelerator: Accelerator,
    /// E3 hardware class throttle (`device-class=a|b|c`).
    pub device_class: DeviceClass,
    /// Max frames per stacked dispatch (`batch`, 1..=[`MAX_BATCH`]).
    pub batch: usize,
    /// Max wait for batch stragglers (`latency-budget`, milliseconds).
    pub latency_budget: Duration,
    /// Device-lane vs blocking dispatch (`dispatch=async|block`).
    pub dispatch: DispatchMode,
}

impl Default for TensorFilterProps {
    fn default() -> Self {
        Self {
            framework: Framework::Xla,
            model: String::new(),
            accelerator: Accelerator::Cpu,
            device_class: DeviceClass::Pc,
            batch: 1,
            latency_budget: Duration::ZERO,
            dispatch: DispatchMode::Async,
        }
    }
}

impl TensorFilterProps {
    fn effective_batch(&self) -> usize {
        self.batch.max(1)
    }
}

impl Props for TensorFilterProps {
    const FACTORY: &'static str = "tensor_filter";
    const KEYS: &'static [&'static str] = &[
        "framework",
        "model",
        "accelerator",
        "device-class",
        "batch",
        "latency-budget",
        "dispatch",
    ];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "framework" => self.framework = Framework::parse(value)?,
            "model" => self.model = value.to_string(),
            "accelerator" => self.accelerator = Accelerator::parse(value)?,
            "device-class" => self.device_class = DeviceClass::parse(value)?,
            "batch" => {
                let n: usize = value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected integer".into(),
                })?;
                if n == 0 || n > MAX_BATCH {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: format!("batch must be in 1..={MAX_BATCH}"),
                    });
                }
                self.batch = n;
            }
            "latency-budget" => {
                let ms: f64 = value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected milliseconds".into(),
                })?;
                if ms.is_nan() || ms < 0.0 {
                    return Err(Error::Property {
                        key: key.into(),
                        value: value.into(),
                        reason: "latency budget must be >= 0".into(),
                    });
                }
                self.latency_budget = Duration::from_secs_f64(ms / 1e3);
            }
            "dispatch" => self.dispatch = DispatchMode::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorFilter::from_props(self)?))
    }
}

/// One stashed in-flight dispatch: the input frames whose outputs are not
/// emitted yet, plus where those outputs come from. At most one job is in
/// flight per filter — the task parks until it drains.
enum PendingJob {
    /// In flight on a device queue; the completion wakes the task.
    Device {
        completion: Completion,
        frames: Vec<Buffer>,
    },
    /// Outputs already computed, held until the modeled envelope deadline
    /// (the task parks on the timer wheel instead of sleeping). `pad` is
    /// the busy time to charge on emit so utilization accounting matches
    /// the blocking path.
    Envelope {
        deadline: Instant,
        pad: Duration,
        outputs: Vec<Vec<Chunk>>,
        frames: Vec<Buffer>,
    },
}

pub struct TensorFilter {
    props: TensorFilterProps,
    plugin: Option<Box<dyn Nnfw>>,
    /// Waker handed to the device on async submits; the completion fires
    /// it to un-park this filter's task.
    wake: Option<Arc<SharedWaker>>,
    pending: Option<PendingJob>,
}

impl TensorFilter {
    pub fn new() -> Self {
        Self::from_props(TensorFilterProps::default()).expect("defaults are valid")
    }

    /// Drain up to `batch - 1` additional ready frames from the input
    /// inbox into `frames`, honoring the latency budget. Anything that
    /// is not a pad-0 buffer (EOS in particular) is pushed back for the
    /// scheduler. On the pooled executor the budget wait holds one
    /// worker for at most `latency-budget` (bounded by construction);
    /// upstream tasks fill the inbox from *other* workers, so on a
    /// fully-busy or single-worker pool the wait gathers only what was
    /// already queued — batches come out smaller, never incorrect.
    fn gather_batch(&self, frames: &mut Vec<Buffer>, ctx: &mut Ctx) {
        let deadline = Instant::now() + self.props.latency_budget;
        while frames.len() < self.props.effective_batch() {
            match ctx.try_pull_input() {
                Some((0, Item::Buffer(b))) => frames.push(b),
                Some((pad, item)) => {
                    ctx.push_back_input(pad, item);
                    return;
                }
                None => {
                    let now = Instant::now();
                    if now >= deadline {
                        return;
                    }
                    match ctx.pull_input_timeout(deadline - now) {
                        Some((0, Item::Buffer(b))) => frames.push(b),
                        Some((pad, item)) => {
                            ctx.push_back_input(pad, item);
                            return;
                        }
                        None => return,
                    }
                }
            }
        }
    }

    fn load_plugin(&mut self, in_infos: &[TensorInfo]) -> Result<()> {
        let plugin: Box<dyn Nnfw> = match &self.props.framework {
            Framework::Xla => Box::new(XlaNnfw::load(
                &self.props.model,
                self.props.accelerator,
                self.props.device_class,
            )?),
            Framework::Custom => Box::new(CustomNnfw::load(&self.props.model)?),
            Framework::Passthrough => Box::new(PassthroughNnfw {
                info: in_infos.to_vec(),
            }),
            Framework::Plugin(name) => crate::nnfw::make_nnfw(
                name,
                &crate::nnfw::NnfwRequest {
                    model: &self.props.model,
                    accelerator: self.props.accelerator,
                    device_class: self.props.device_class,
                    input_infos: in_infos,
                },
            )?,
        };
        // validate input compatibility (element count + dtype per tensor)
        let expect = plugin.inputs();
        if expect.len() != in_infos.len() {
            return Err(Error::Negotiation(format!(
                "tensor_filter {}: model wants {} input tensors, caps carry {}",
                self.props.model,
                expect.len(),
                in_infos.len()
            )));
        }
        for (have, want) in in_infos.iter().zip(&expect) {
            if have.dtype != want.dtype {
                return Err(Error::Negotiation(format!(
                    "tensor_filter {}: input dtype {} != model {}",
                    self.props.model, have.dtype, want.dtype
                )));
            }
            if have.dims.num_elements() != want.dims.num_elements() {
                return Err(Error::Negotiation(format!(
                    "tensor_filter {}: input {} has {} elements, model wants {} ({})",
                    self.props.model,
                    have.dims,
                    have.dims.num_elements(),
                    want.dims.num_elements(),
                    want.dims
                )));
            }
        }
        self.plugin = Some(plugin);
        Ok(())
    }

    fn element_err(&self, e: impl std::fmt::Display) -> Error {
        Error::element(
            format!("tensor_filter({})", self.props.model),
            e.to_string(),
        )
    }

    /// De-batch `outs` onto the src pad: each result keeps its frame's
    /// timestamp, sequence number and duration.
    fn emit_outputs(
        &self,
        frames: &[Buffer],
        outs: Vec<Vec<Chunk>>,
        ctx: &mut Ctx,
    ) -> Result<Flow> {
        if outs.len() != frames.len() {
            return Err(self.element_err(format!(
                "batch of {} produced {} results",
                frames.len(),
                outs.len()
            )));
        }
        for (frame, chunks) in frames.iter().zip(outs) {
            let mut out = Buffer::new(frame.pts_ns, chunks);
            out.seq = frame.seq;
            out.duration_ns = frame.duration_ns;
            ctx.push(0, out)?;
        }
        Ok(Flow::Continue)
    }
}

impl Default for TensorFilter {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorFilter {
    type Props = TensorFilterProps;

    fn from_props(props: TensorFilterProps) -> Result<Self> {
        // same invariant as the string front-end: batch in 1..=MAX_BATCH
        if props.batch == 0 || props.batch > MAX_BATCH {
            return Err(Error::Property {
                key: "batch".into(),
                value: props.batch.to_string(),
                reason: format!("batch must be in 1..={MAX_BATCH}"),
            });
        }
        Ok(Self {
            props,
            plugin: None,
            wake: None,
            pending: None,
        })
    }
}

impl Element for TensorFilter {
    fn type_name(&self) -> &'static str {
        "tensor_filter"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    /// A batching filter needs channel headroom to aggregate from.
    fn preferred_input_capacity(&self) -> usize {
        let batch = self.props.effective_batch();
        if batch > 1 {
            batch * 2
        } else {
            1
        }
    }

    fn domain(&self) -> Domain {
        if self.props.accelerator == Accelerator::Npu {
            Domain::Npu
        } else {
            Domain::Cpu
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let (in_infos, fps) = match &in_caps[0] {
            Caps::Tensor { info, fps_millis } => (vec![info.clone()], *fps_millis),
            Caps::Tensors { infos, fps_millis } => (infos.clone(), *fps_millis),
            other => {
                return Err(Error::Negotiation(format!(
                    "tensor_filter needs tensor input, got {other}"
                )))
            }
        };
        self.load_plugin(&in_infos)?;
        let outs = self.plugin.as_ref().unwrap().outputs();
        let caps = if outs.len() == 1 {
            Caps::Tensor {
                info: outs[0].clone(),
                fps_millis: fps,
            }
        } else {
            Caps::Tensors {
                infos: outs,
                fps_millis: fps,
            }
        };
        Ok(vec![caps; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        debug_assert!(
            self.pending.is_none(),
            "tensor_filter got new input with a job in flight"
        );
        let batch = self.props.effective_batch();
        let mut frames = Vec::with_capacity(batch);
        frames.push(buf);
        if batch > 1 {
            self.gather_batch(&mut frames, ctx);
        }
        // the device lane needs a task waker to resume on; without one
        // (bare contexts, dispatch=block) fall back to the blocking path
        let lane = self.props.dispatch == DispatchMode::Async && ctx.has_waker();
        let waker = if lane {
            let w = self.wake.get_or_insert_with(SharedWaker::new).clone();
            w.set(ctx.waker());
            Some(w)
        } else {
            None
        };
        let invoked = {
            let plugin = self
                .plugin
                .as_ref()
                .ok_or_else(|| Error::element("tensor_filter", "not negotiated"))?;
            let chunk_refs: Vec<Vec<&Chunk>> = frames
                .iter()
                .map(|b| b.chunks.iter().collect())
                .collect();
            let frame_refs: Vec<&[&Chunk]> =
                chunk_refs.iter().map(|v| v.as_slice()).collect();
            let r = if lane {
                plugin.invoke_batch_async(&frame_refs, waker)
            } else {
                plugin.invoke_batch(&frame_refs).map(AsyncInvoke::Ready)
            };
            r.map_err(|e| self.element_err(e))?
        };
        match invoked {
            AsyncInvoke::Ready(outs) => self.emit_outputs(&frames, outs, ctx),
            AsyncInvoke::After {
                deadline,
                pad,
                outputs,
            } => {
                if ctx.park_until(deadline) {
                    ctx.record_device_submit();
                    self.pending = Some(PendingJob::Envelope {
                        deadline,
                        pad,
                        outputs,
                        frames,
                    });
                    Ok(Flow::Wait)
                } else {
                    // deadline already passed (or no waker — the call
                    // slept in place): the envelope is paid, emit now
                    ctx.charge_busy(pad);
                    self.emit_outputs(&frames, outputs, ctx)
                }
            }
            AsyncInvoke::Pending(completion) => {
                ctx.record_device_submit();
                self.pending = Some(PendingJob::Device { completion, frames });
                Ok(Flow::Wait)
            }
        }
    }

    /// Re-entered (instead of `handle`) after a `Flow::Wait`: drain the
    /// in-flight job if its completion fired, or keep parking on a
    /// spurious wake. The wheel entry / device completion that is still
    /// outstanding will wake the task again, so a spurious pass never
    /// needs to re-arm.
    fn resume(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        let Some(job) = self.pending.take() else {
            return Ok(Flow::Continue);
        };
        match job {
            PendingJob::Device { completion, frames } => {
                match completion.try_take() {
                    Some(done) => {
                        ctx.record_device_completion();
                        // modeled queue+service occupancy: what the
                        // blocking dispatch would have burned in-step
                        ctx.charge_busy(done.occupancy);
                        let outs =
                            done.result.map_err(|e| self.element_err(e))?;
                        self.emit_outputs(&frames, outs, ctx)
                    }
                    None => {
                        self.pending =
                            Some(PendingJob::Device { completion, frames });
                        Ok(Flow::Wait)
                    }
                }
            }
            PendingJob::Envelope {
                deadline,
                pad,
                outputs,
                frames,
            } => {
                if Instant::now() < deadline {
                    self.pending = Some(PendingJob::Envelope {
                        deadline,
                        pad,
                        outputs,
                        frames,
                    });
                    return Ok(Flow::Wait);
                }
                ctx.record_device_completion();
                ctx.charge_busy(pad);
                self.emit_outputs(&frames, outputs, ctx)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};
    use crate::tensor::{Chunk, DType};

    #[test]
    fn passthrough_filter() {
        let mut f = TensorFilter::new();
        f.set_property("framework", "passthrough").unwrap();
        let caps = Caps::tensor(DType::F32, [4], 30.0);
        let out_caps = f.negotiate(&[caps.clone()], 1).unwrap();
        assert!(out_caps[0].compatible(&caps));
        let (mut ctx, rxs) = ctx_with_outputs(1);
        f.handle(0, Item::Buffer(Buffer::from_f32(7, &[1., 2., 3., 4.])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out[0].pts_ns, 7);
        assert_eq!(out[0].chunk().as_f32().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn xla_filter_end_to_end() {
        let mut f = TensorFilter::from_props(TensorFilterProps {
            framework: Framework::Xla,
            model: "ars_a_opt".into(),
            ..Default::default()
        })
        .unwrap();
        // ars_a: (1,128,3) f32 -> minor-first stream dims 3:128:1
        let caps = Caps::tensor(DType::F32, [3, 128, 1], 10.0);
        let out_caps = f.negotiate(&[caps], 1).unwrap();
        match &out_caps[0] {
            Caps::Tensor { info, .. } => {
                assert_eq!(info.dims.num_elements(), 8);
            }
            other => panic!("unexpected {other:?}"),
        }
        let (mut ctx, rxs) = ctx_with_outputs(1);
        let input = Buffer::single(0, Chunk::from_f32(&vec![0.3f32; 128 * 3]));
        f.handle(0, Item::Buffer(input), &mut ctx).unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        let probs = out[0].chunk().to_f32_vec().unwrap();
        assert_eq!(probs.len(), 8);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn batch_property_validated() {
        let mut f = TensorFilter::new();
        f.set_property("batch", "4").unwrap();
        assert_eq!(f.preferred_input_capacity(), 8);
        f.set_property("latency-budget", "2.5").unwrap();
        assert!(f.set_property("batch", "0").is_err());
        assert!(f
            .set_property("batch", &(MAX_BATCH + 1).to_string())
            .is_err());
        assert!(f.set_property("batch", "x").is_err());
        assert!(f.set_property("latency-budget", "-1").is_err());
        assert!(f.set_property("framework", "tensorflow").is_err());
    }

    #[test]
    fn batched_filter_without_queued_input_runs_partial_batches() {
        // the testutil ctx has no input channel: every handle() call is a
        // batch of one, and must still produce one output per input
        let mut f = TensorFilter::new();
        f.set_property("framework", "passthrough").unwrap();
        f.set_property("batch", "4").unwrap();
        let caps = Caps::tensor(DType::F32, [3], 30.0);
        f.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        for i in 0..3u64 {
            let buf = Buffer::from_f32(i * 100, &[i as f32, 1.0, 2.0]);
            f.handle(0, Item::Buffer(buf), &mut ctx).unwrap();
        }
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out.len(), 3);
        for (i, b) in out.iter().enumerate() {
            assert_eq!(b.pts_ns, i as u64 * 100);
            assert_eq!(b.chunk().as_f32().unwrap()[0], i as f32);
        }
    }

    #[test]
    fn registered_nnfw_routes_through_framework_dispatch() {
        use crate::nnfw::{register_nnfw, Nnfw};
        use crate::tensor::TensorInfo;

        struct Doubler {
            info: Vec<TensorInfo>,
        }
        impl Nnfw for Doubler {
            fn inputs(&self) -> Vec<TensorInfo> {
                self.info.clone()
            }
            fn outputs(&self) -> Vec<TensorInfo> {
                self.info.clone()
            }
            fn invoke(&self, inputs: &[&Chunk]) -> crate::error::Result<Vec<Chunk>> {
                inputs
                    .iter()
                    .map(|c| {
                        let v = c.to_f32_vec()?;
                        Ok(Chunk::from_f32(
                            &v.iter().map(|x| x * 2.0).collect::<Vec<_>>(),
                        ))
                    })
                    .collect()
            }
        }
        register_nnfw("unit_doubler", |req| {
            Ok(Box::new(Doubler {
                info: req.input_infos.to_vec(),
            }))
        });

        let mut f = TensorFilter::new();
        f.set_property("framework", "unit_doubler").unwrap();
        assert_eq!(f.props.framework, Framework::Plugin("unit_doubler".into()));
        let caps = Caps::tensor(DType::F32, [3], 30.0);
        f.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        f.handle(0, Item::Buffer(Buffer::from_f32(0, &[1., 2., 3.])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        assert_eq!(out[0].chunk().as_f32().unwrap(), &[2., 4., 6.]);
    }

    #[test]
    fn unknown_framework_suggests_registered_name() {
        use crate::nnfw::register_nnfw;
        register_nnfw("mockfw", |_req| {
            Err(crate::error::Error::Runtime("unused".into()))
        });
        // close typo of a registered sub-plugin
        let err = Framework::parse("mockfv").unwrap_err().to_string();
        assert!(err.contains("did you mean \"mockfw\"?"), "{err}");
        // close typo of a built-in
        let err = Framework::parse("pasthrough").unwrap_err().to_string();
        assert!(err.contains("did you mean \"passthrough\"?"), "{err}");
        // far-away garbage: error, no suggestion
        let err = Framework::parse("tensorflow-lite-gpu").unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn rejects_wrong_input_size() {
        let mut f = TensorFilter::new();
        f.set_property("model", "ars_a_opt").unwrap();
        let caps = Caps::tensor(DType::F32, [7], 10.0);
        assert!(f.negotiate(&[caps], 1).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let mut f = TensorFilter::new();
        f.set_property("model", "ars_a_opt").unwrap();
        let caps = Caps::tensor(DType::U8, [3, 128, 1], 10.0);
        assert!(f.negotiate(&[caps], 1).is_err());
    }
}
