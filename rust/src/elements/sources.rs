//! Source elements: `videotestsrc`, `appsrc`, `sensorsrc` (Tensor-Src-IIO
//! analog), `filesrc`.

use std::sync::Arc;

use crate::element::props::{parse_bool, unknown_property};
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Result};
use crate::pipeline::executor::SharedWaker;
use crate::pipeline::stream::{Endpoint, EpPop, DEFAULT_ENDPOINT_CAPACITY};
use crate::tensor::{
    Buffer, Caps, Chunk, ChunkPool, DType, Dims, TensorInfo, VideoFormat, VideoInfo,
};
use crate::video::convert_into;
use crate::video::pattern::{generate_rgb_into, splitmix64, Pattern};

/// Typed properties of [`VideoTestSrc`].
#[derive(Debug, Clone)]
pub struct VideoTestSrcProps {
    /// Synthetic pattern (`pattern`).
    pub pattern: Pattern,
    /// Stop after this many frames (`num-buffers`; `None` = unbounded).
    pub num_buffers: Option<u64>,
    /// Pace frame production to the framerate (`is-live`).
    pub is_live: bool,
    /// Output pixel format (`format`).
    pub format: VideoFormat,
    pub width: usize,
    pub height: usize,
    /// Frames per second (`framerate`).
    pub framerate: f64,
}

impl Default for VideoTestSrcProps {
    fn default() -> Self {
        Self {
            pattern: Pattern::Smpte,
            num_buffers: None,
            is_live: false,
            format: VideoFormat::Rgb,
            width: 640,
            height: 480,
            framerate: 30.0,
        }
    }
}

impl VideoTestSrcProps {
    fn video_info(&self) -> VideoInfo {
        VideoInfo::new(self.format, self.width, self.height, self.framerate)
    }
}

impl Props for VideoTestSrcProps {
    const FACTORY: &'static str = "videotestsrc";
    const KEYS: &'static [&'static str] = &[
        "pattern",
        "num-buffers",
        "is-live",
        "format",
        "width",
        "height",
        "framerate",
    ];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "pattern" => self.pattern = Pattern::parse(value)?,
            "num-buffers" => {
                self.num_buffers = Some(value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected integer".into(),
                })?)
            }
            "is-live" => self.is_live = parse_bool(value),
            "format" => self.format = VideoFormat::parse(value)?,
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            "framerate" => self.framerate = parse_f64(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(VideoTestSrc::from_props(self)?))
    }
}

/// Procedural raw-video source with live pacing (like GStreamer's
/// `videotestsrc is-live=true`). The caps can also come from a downstream
/// capsfilter, which overrides the geometry properties.
pub struct VideoTestSrc {
    props: VideoTestSrcProps,
    /// Effective output caps: from the props unless a downstream
    /// capsfilter proposal overrode them.
    info: VideoInfo,
    n: u64,
}

impl VideoTestSrc {
    pub fn new() -> Self {
        Self::from_props(VideoTestSrcProps::default()).expect("defaults are valid")
    }
}

impl Default for VideoTestSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for VideoTestSrc {
    type Props = VideoTestSrcProps;

    fn from_props(props: VideoTestSrcProps) -> Result<Self> {
        let info = props.video_info();
        Ok(Self { props, info, n: 0 })
    }
}

impl Element for VideoTestSrc {
    fn type_name(&self) -> &'static str {
        "videotestsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)?;
        // sync only the touched field into the effective caps — a full
        // rebuild would discard geometry negotiated via propose_caps
        match key {
            "format" => self.info.format = self.props.format,
            "width" => self.info.width = self.props.width,
            "height" => self.info.height = self.props.height,
            "framerate" => {
                self.info.fps_millis = (self.props.framerate * 1000.0).round() as u64
            }
            _ => {}
        }
        Ok(())
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![Caps::Video(self.info.clone()); n_srcs.max(1)])
    }

    fn propose_caps(&mut self, downstream: &Caps) -> Result<()> {
        if let Caps::Video(v) = downstream {
            self.info = v.clone();
        }
        Ok(())
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!("source has no sink pads")
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if let Some(max) = self.props.num_buffers {
            if self.n >= max {
                return Ok(Flow::Eos);
            }
        }
        let fps = self.info.fps().max(0.001);
        let frame_dur_ns = (1e9 / fps) as u64;
        let pts = self.n * frame_dur_ns;
        if self.props.is_live {
            if ctx.stopped() {
                return Ok(Flow::Eos);
            }
            // pace on the timer wheel: the task parks until the frame's
            // wall-clock due time and this step re-runs (n unchanged)
            if ctx.park_until_pts(pts) {
                return Ok(Flow::Wait);
            }
        }
        // generate into pooled storage: steady-state frame production
        // reuses the previous frames' allocations
        let pool = ChunkPool::global();
        let (w, h) = (self.info.width, self.info.height);
        let data = if self.info.format == VideoFormat::Rgb {
            let mut rgb = pool.take(w * h * 3);
            generate_rgb_into(self.props.pattern, w, h, self.n, &mut rgb);
            rgb
        } else {
            let mut rgb = pool.take(w * h * 3);
            generate_rgb_into(self.props.pattern, w, h, self.n, &mut rgb);
            let mut out = pool.take(self.info.frame_size());
            convert_into(VideoFormat::Rgb, self.info.format, w, h, &rgb, &mut out);
            pool.recycle(rgb);
            out
        };
        let mut buf = Buffer::single(pts, Chunk::from_pooled(data));
        buf.duration_ns = frame_dur_ns;
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`AppSrc`].
#[derive(Debug, Clone)]
pub struct AppSrcProps {
    /// Caps this source announces downstream (`caps`).
    pub caps: Caps,
}

impl Default for AppSrcProps {
    fn default() -> Self {
        Self { caps: Caps::Any }
    }
}

impl Props for AppSrcProps {
    const FACTORY: &'static str = "appsrc";
    const KEYS: &'static [&'static str] = &["caps"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "caps" => self.caps = Caps::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(AppSrc::from_props(self)?))
    }
}

/// `appsrc`: the application pushes buffers into the pipeline.
///
/// Since the stream-endpoint redesign this is a thin wrapper over the
/// same bounded `Endpoint` primitive that backs topic subscriptions
/// (`pipeline/stream.rs`) — an anonymous local topic with the element as
/// its only consumer. On the pooled executor the source never blocks a
/// worker waiting for application data: an empty endpoint parks its task
/// ([`Flow::Wait`]) and the push handle wakes it through a
/// [`SharedWaker`] the element publishes at its first step.
pub struct AppSrc {
    ep: Arc<Endpoint>,
    wake: Arc<SharedWaker>,
    props: AppSrcProps,
    n: u64,
}

/// Cloneable, thread-safe handle for pushing data into a running pipeline.
///
/// Obtain it from [`AppSrc::handle`] or
/// [`Pipeline::appsrc`](crate::pipeline::Pipeline::appsrc) before the
/// pipeline starts; pushes from any thread after that.
#[derive(Clone)]
pub struct AppSrcHandle {
    ep: Arc<Endpoint>,
    wake: Arc<SharedWaker>,
}

impl AppSrcHandle {
    /// Push a buffer into the playing pipeline (blocking while the
    /// pipeline is saturated).
    ///
    /// ```
    /// use nnstreamer::elements::sinks::AppSinkProps;
    /// use nnstreamer::elements::sources::AppSrcProps;
    /// use nnstreamer::pipeline::PipelineBuilder;
    /// use nnstreamer::tensor::{Buffer, Caps, DType};
    ///
    /// # fn main() -> nnstreamer::Result<()> {
    /// let mut b = PipelineBuilder::new();
    /// b.chain_named("in", AppSrcProps { caps: Caps::tensor(DType::F32, [2], 0.0) })?
    ///     .chain_named("out", AppSinkProps::default())?;
    /// let mut pipeline = b.build();
    /// let push = pipeline.appsrc("in")?;
    /// let frames = pipeline.appsink("out")?;
    /// let running = pipeline.play()?;
    ///
    /// push.push(Buffer::from_f32(0, &[1.0, 2.0]))?;
    /// assert_eq!(frames.recv().unwrap().chunk().as_f32()?, &[1.0, 2.0]);
    ///
    /// push.end();
    /// running.wait()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn push(&self, buf: Buffer) -> Result<()> {
        self.ep
            .push_blocking(buf)
            .map_err(|_| Error::Runtime("appsrc: pipeline gone".into()))?;
        // unpark the source task if it was waiting for data
        self.wake.wake();
        Ok(())
    }

    /// Signal end of stream (already-queued buffers still drain first).
    pub fn end(&self) {
        self.ep.set_eos();
        self.wake.wake();
    }
}

impl AppSrc {
    pub fn new() -> Self {
        Self::from_props(AppSrcProps::default()).expect("defaults are valid")
    }

    /// Get a push handle (call before `Pipeline::play`).
    pub fn handle(&self) -> AppSrcHandle {
        AppSrcHandle {
            ep: self.ep.clone(),
            wake: self.wake.clone(),
        }
    }

    /// Set the caps this source will announce.
    pub fn set_caps(&mut self, caps: Caps) {
        self.props.caps = caps;
    }
}

impl Default for AppSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for AppSrc {
    fn drop(&mut self) {
        // the consumer is gone: pending and future pushes must fail with
        // "pipeline gone" instead of blocking the application forever
        // (the endpoint analog of dropping the old mpsc receiver)
        self.ep.close();
    }
}

impl FromProps for AppSrc {
    type Props = AppSrcProps;

    fn from_props(props: AppSrcProps) -> Result<Self> {
        let ep = Endpoint::standalone(DEFAULT_ENDPOINT_CAPACITY);
        let wake = SharedWaker::new();
        // the element task is the endpoint's consumer; pushes wake it
        ep.add_consumer_waker(&wake);
        Ok(Self {
            ep,
            wake,
            props,
            n: 0,
        })
    }
}

impl Element for AppSrc {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "appsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![self.props.caps.clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        // publish the task waker first, so a push racing this step's
        // empty check still lands a wake (the executor's wake-pending
        // flag covers the remainder of the window)
        self.wake.set(ctx.waker());
        match self.ep.try_pop() {
            EpPop::Item(mut buf) => {
                buf.seq = self.n;
                self.n += 1;
                ctx.push(0, buf)?;
                Ok(Flow::Continue)
            }
            EpPop::End => Ok(Flow::Eos),
            // nothing pushed yet: park until the application wakes us
            EpPop::Empty => Ok(Flow::Wait),
        }
    }
}

/// Waveform kind produced by [`SensorSrc`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SensorKind {
    Accel,
    Pressure,
    Mic,
}

impl SensorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "accel" => SensorKind::Accel,
            "pressure" => SensorKind::Pressure,
            "mic" => SensorKind::Mic,
            _ => {
                return Err(Error::Property {
                    key: "kind".into(),
                    value: s.into(),
                    reason: "accel|pressure|mic".into(),
                })
            }
        })
    }
}

/// Typed properties of [`SensorSrc`].
#[derive(Debug, Clone)]
pub struct SensorSrcProps {
    /// Waveform family (`kind`).
    pub kind: SensorKind,
    /// Windows per second (`rate`).
    pub rate: f64,
    pub num_buffers: Option<u64>,
    pub is_live: bool,
    /// Samples per window (`window`).
    pub window: usize,
    pub channels: usize,
    pub seed: u64,
}

impl Default for SensorSrcProps {
    fn default() -> Self {
        Self {
            kind: SensorKind::Accel,
            rate: 10.0,
            num_buffers: None,
            is_live: false,
            window: 128,
            channels: 3,
            seed: 17,
        }
    }
}

impl Props for SensorSrcProps {
    const FACTORY: &'static str = "sensorsrc";
    const KEYS: &'static [&'static str] = &[
        "kind",
        "rate",
        "num-buffers",
        "is-live",
        "window",
        "channels",
        "seed",
    ];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "kind" => self.kind = SensorKind::parse(value)?,
            "rate" => self.rate = parse_f64(key, value)?,
            "num-buffers" => self.num_buffers = Some(parse_usize(key, value)? as u64),
            "is-live" => self.is_live = parse_bool(value),
            "window" => self.window = parse_usize(key, value)?,
            "channels" => self.channels = parse_usize(key, value)?,
            "seed" => self.seed = parse_usize(key, value)? as u64,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(SensorSrc::from_props(self)?))
    }
}

/// Synthetic IIO-style sensor source (`Tensor-Src-IIO` analog): emits
/// `other/tensor` windows of waveform data with activity segments, standing
/// in for the accelerometer/pressure sensors of the ARS device (E2).
pub struct SensorSrc {
    props: SensorSrcProps,
    n: u64,
}

impl SensorSrc {
    pub fn new() -> Self {
        Self::from_props(SensorSrcProps::default()).expect("defaults are valid")
    }

    fn sample(&self, t: f64, ch: usize, idx: u64) -> f32 {
        // activity segments switch every ~3 seconds, deterministic
        let segment = (t / 3.0) as u64;
        let activity = splitmix64(self.props.seed ^ segment) % 4;
        let base = match self.props.kind {
            SensorKind::Accel => {
                let f = 0.8 + activity as f64 * 1.7;
                (2.0 * std::f64::consts::PI * f * t + ch as f64).sin()
                    * (0.3 + 0.5 * activity as f64)
            }
            SensorKind::Pressure => 1013.0 + (t * 0.05).sin() * 2.0 + activity as f64 * 0.3,
            SensorKind::Mic => {
                let f = 200.0 + activity as f64 * 400.0;
                (2.0 * std::f64::consts::PI * f * t).sin() * 0.4
            }
        };
        let noise = (splitmix64(idx ^ (ch as u64) << 32 ^ self.props.seed) % 1000) as f64
            / 1000.0
            - 0.5;
        (base + noise * 0.05) as f32
    }
}

impl Default for SensorSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for SensorSrc {
    type Props = SensorSrcProps;

    fn from_props(props: SensorSrcProps) -> Result<Self> {
        Ok(Self { props, n: 0 })
    }
}

impl Element for SensorSrc {
    fn type_name(&self) -> &'static str {
        "sensorsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        // layout is [sample][channel]: channels vary fastest -> minor-first dims
        let info = TensorInfo::new(
            DType::F32,
            Dims::new(&[self.props.channels, self.props.window]),
        );
        Ok(vec![
            Caps::Tensor {
                info,
                fps_millis: (self.props.rate * 1000.0) as u64,
            };
            n_srcs.max(1)
        ])
    }

    fn propose_caps(&mut self, downstream: &Caps) -> Result<()> {
        if let Caps::Tensor { info, fps_millis } = downstream {
            if info.dtype == DType::F32 && info.dims.effective_rank() <= 2 {
                self.props.channels = info.dims.dim_or_1(0);
                self.props.window = info.dims.dim_or_1(1);
                if *fps_millis > 0 {
                    self.props.rate = *fps_millis as f64 / 1000.0;
                }
            }
        }
        Ok(())
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if let Some(max) = self.props.num_buffers {
            if self.n >= max {
                return Ok(Flow::Eos);
            }
        }
        let dur_ns = (1e9 / self.props.rate.max(0.001)) as u64;
        let pts = self.n * dur_ns;
        if self.props.is_live {
            if ctx.stopped() {
                return Ok(Flow::Eos);
            }
            if ctx.park_until_pts(pts) {
                return Ok(Flow::Wait);
            }
        }
        let (window, channels) = (self.props.window, self.props.channels);
        let t_window = 1.0 / self.props.rate.max(0.001);
        let mut data = vec![0f32; window * channels];
        for s in 0..window {
            let t = self.n as f64 * t_window + s as f64 * t_window / window as f64;
            for c in 0..channels {
                data[s * channels + c] =
                    self.sample(t, c, self.n * window as u64 + s as u64);
            }
        }
        let mut buf = Buffer::from_f32(pts, &data);
        buf.duration_ns = dur_ns;
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`FileSrc`].
#[derive(Debug, Clone, Default)]
pub struct FileSrcProps {
    /// Path to read (`location`).
    pub location: String,
    /// Bytes per buffer; 0 emits the whole file as one buffer
    /// (`blocksize`).
    pub blocksize: usize,
}

impl Props for FileSrcProps {
    const FACTORY: &'static str = "filesrc";
    const KEYS: &'static [&'static str] = &["location", "blocksize"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "location" => self.location = value.to_string(),
            "blocksize" => self.blocksize = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(FileSrc::from_props(self)?))
    }
}

/// Reads a file and emits it as fixed-size binary frames.
pub struct FileSrc {
    props: FileSrcProps,
    data: Option<Arc<Vec<u8>>>,
    offset: usize,
    n: u64,
}

impl FileSrc {
    pub fn new() -> Self {
        Self::from_props(FileSrcProps::default()).expect("defaults are valid")
    }
}

impl Default for FileSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for FileSrc {
    type Props = FileSrcProps;

    fn from_props(props: FileSrcProps) -> Result<Self> {
        Ok(Self {
            props,
            data: None,
            offset: 0,
            n: 0,
        })
    }
}

impl Element for FileSrc {
    fn type_name(&self) -> &'static str {
        "filesrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        if self.props.location.is_empty() {
            return Err(Error::Negotiation("filesrc needs location=".into()));
        }
        Ok(vec![Caps::Any; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if self.data.is_none() {
            self.data = Some(Arc::new(std::fs::read(&self.props.location)?));
        }
        let data = self.data.as_ref().unwrap().clone();
        if self.offset >= data.len() {
            return Ok(Flow::Eos);
        }
        let end = if self.props.blocksize == 0 {
            data.len()
        } else {
            (self.offset + self.props.blocksize).min(data.len())
        };
        let chunk = Chunk::from_vec(data[self.offset..end].to_vec());
        self.offset = end;
        let mut buf = Buffer::single(0, chunk);
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

pub(crate) fn parse_usize(key: &str, value: &str) -> Result<usize> {
    value.parse().map_err(|_| Error::Property {
        key: key.into(),
        value: value.into(),
        reason: "expected integer".into(),
    })
}

pub(crate) fn parse_f64(key: &str, value: &str) -> Result<f64> {
    value.parse().map_err(|_| Error::Property {
        key: key.into(),
        value: value.into(),
        reason: "expected number".into(),
    })
}
