//! Source elements: `videotestsrc`, `appsrc`, `sensorsrc` (Tensor-Src-IIO
//! analog), `filesrc`.

use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;

use crate::element::{Ctx, Element, Flow, Item, PadSpec};
use crate::error::{Error, Result};
use crate::tensor::{
    Buffer, Caps, Chunk, ChunkPool, DType, Dims, TensorInfo, VideoFormat, VideoInfo,
};
use crate::video::convert_into;
use crate::video::pattern::{generate_rgb_into, splitmix64, Pattern};

/// Procedural raw-video source with live pacing (like GStreamer's
/// `videotestsrc is-live=true`).
///
/// Properties: `pattern`, `num-buffers`, `is-live`, `format`, `width`,
/// `height`, `framerate` (the caps can also come from a downstream
/// capsfilter, which overrides these).
pub struct VideoTestSrc {
    pattern: Pattern,
    num_buffers: Option<u64>,
    is_live: bool,
    info: VideoInfo,
    n: u64,
}

impl VideoTestSrc {
    pub fn new() -> Self {
        Self {
            pattern: Pattern::Smpte,
            num_buffers: None,
            is_live: false,
            info: VideoInfo::new(VideoFormat::Rgb, 640, 480, 30.0),
            n: 0,
        }
    }
}

impl Default for VideoTestSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for VideoTestSrc {
    fn type_name(&self) -> &'static str {
        "videotestsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "pattern" => self.pattern = Pattern::parse(value)?,
            "num-buffers" => {
                self.num_buffers = Some(value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected integer".into(),
                })?)
            }
            "is-live" => self.is_live = value == "true" || value == "1",
            "format" => self.info.format = VideoFormat::parse(value)?,
            "width" => self.info.width = parse_usize(key, value)?,
            "height" => self.info.height = parse_usize(key, value)?,
            "framerate" => {
                let fps: f64 = value.parse().map_err(|_| Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "expected number".into(),
                })?;
                self.info.fps_millis = (fps * 1000.0).round() as u64;
            }
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of videotestsrc".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![Caps::Video(self.info.clone()); n_srcs.max(1)])
    }

    fn propose_caps(&mut self, downstream: &Caps) -> Result<()> {
        if let Caps::Video(v) = downstream {
            self.info = v.clone();
        }
        Ok(())
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!("source has no sink pads")
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if let Some(max) = self.num_buffers {
            if self.n >= max {
                return Ok(Flow::Eos);
            }
        }
        let fps = self.info.fps().max(0.001);
        let frame_dur_ns = (1e9 / fps) as u64;
        let pts = self.n * frame_dur_ns;
        if self.is_live {
            ctx.sleep_until_pts(pts);
            if ctx.stopped() {
                return Ok(Flow::Eos);
            }
        }
        // generate into pooled storage: steady-state frame production
        // reuses the previous frames' allocations
        let pool = ChunkPool::global();
        let (w, h) = (self.info.width, self.info.height);
        let data = if self.info.format == VideoFormat::Rgb {
            let mut rgb = pool.take(w * h * 3);
            generate_rgb_into(self.pattern, w, h, self.n, &mut rgb);
            rgb
        } else {
            let mut rgb = pool.take(w * h * 3);
            generate_rgb_into(self.pattern, w, h, self.n, &mut rgb);
            let mut out = pool.take(self.info.frame_size());
            convert_into(VideoFormat::Rgb, self.info.format, w, h, &rgb, &mut out);
            pool.recycle(rgb);
            out
        };
        let mut buf = Buffer::single(pts, Chunk::from_pooled(data));
        buf.duration_ns = frame_dur_ns;
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

/// Caps negotiated by a downstream capsfilter also need to reach the src;
/// our negotiation is one-directional (topological), so the test source
/// must be configured directly or via properties. The parser maps a
/// directly-following capsfilter's fields back onto the source as a
/// convenience — handled in `CapsFilter::negotiate` by accepting Any.
///
/// `appsrc`: the application pushes buffers through a channel.
pub struct AppSrc {
    tx: SyncSender<Option<(Buffer, u64)>>,
    rx: Receiver<Option<(Buffer, u64)>>,
    caps: Caps,
    n: u64,
}

/// Cloneable handle for pushing data into a running pipeline.
#[derive(Clone)]
pub struct AppSrcHandle {
    tx: SyncSender<Option<(Buffer, u64)>>,
}

impl AppSrcHandle {
    /// Push a buffer (blocking if the pipeline is saturated).
    pub fn push(&self, buf: Buffer) -> Result<()> {
        self.tx
            .send(Some((buf, 0)))
            .map_err(|_| Error::Runtime("appsrc: pipeline gone".into()))
    }

    /// Signal end of stream.
    pub fn end(&self) {
        let _ = self.tx.send(None);
    }
}

impl AppSrc {
    pub fn new() -> Self {
        let (tx, rx) = std::sync::mpsc::sync_channel(64);
        Self {
            tx,
            rx,
            caps: Caps::Any,
            n: 0,
        }
    }

    /// Get a push handle (call before `Pipeline::play`).
    pub fn handle(&self) -> AppSrcHandle {
        AppSrcHandle {
            tx: self.tx.clone(),
        }
    }

    /// Set the caps this source will announce.
    pub fn set_caps(&mut self, caps: Caps) {
        self.caps = caps;
    }
}

impl Default for AppSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for AppSrc {
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn type_name(&self) -> &'static str {
        "appsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "caps" => {
                self.caps = Caps::parse(value)?;
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of appsrc".into(),
            }),
        }
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![self.caps.clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        match self.rx.recv() {
            Ok(Some((mut buf, _))) => {
                buf.seq = self.n;
                self.n += 1;
                ctx.push(0, buf)?;
                Ok(Flow::Continue)
            }
            Ok(None) | Err(_) => Ok(Flow::Eos),
        }
    }
}

/// Synthetic IIO-style sensor source (`Tensor-Src-IIO` analog): emits
/// `other/tensor` windows of waveform data with activity segments, standing
/// in for the accelerometer/pressure sensors of the ARS device (E2).
///
/// Properties: `kind` (accel|pressure|mic), `rate` (windows per second),
/// `num-buffers`, `is-live`, `window` (samples per window), `channels`.
pub struct SensorSrc {
    kind: SensorKind,
    rate: f64,
    num_buffers: Option<u64>,
    is_live: bool,
    window: usize,
    channels: usize,
    n: u64,
    seed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SensorKind {
    Accel,
    Pressure,
    Mic,
}

impl SensorSrc {
    pub fn new() -> Self {
        Self {
            kind: SensorKind::Accel,
            rate: 10.0,
            num_buffers: None,
            is_live: false,
            window: 128,
            channels: 3,
            n: 0,
            seed: 17,
        }
    }

    fn sample(&self, t: f64, ch: usize, idx: u64) -> f32 {
        // activity segments switch every ~3 seconds, deterministic
        let segment = (t / 3.0) as u64;
        let activity = splitmix64(self.seed ^ segment) % 4;
        let base = match self.kind {
            SensorKind::Accel => {
                let f = 0.8 + activity as f64 * 1.7;
                (2.0 * std::f64::consts::PI * f * t + ch as f64).sin()
                    * (0.3 + 0.5 * activity as f64)
            }
            SensorKind::Pressure => 1013.0 + (t * 0.05).sin() * 2.0 + activity as f64 * 0.3,
            SensorKind::Mic => {
                let f = 200.0 + activity as f64 * 400.0;
                (2.0 * std::f64::consts::PI * f * t).sin() * 0.4
            }
        };
        let noise =
            (splitmix64(idx ^ (ch as u64) << 32 ^ self.seed) % 1000) as f64 / 1000.0 - 0.5;
        (base + noise * 0.05) as f32
    }
}

impl Default for SensorSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for SensorSrc {
    fn type_name(&self) -> &'static str {
        "sensorsrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "kind" => {
                self.kind = match value {
                    "accel" => SensorKind::Accel,
                    "pressure" => SensorKind::Pressure,
                    "mic" => SensorKind::Mic,
                    _ => {
                        return Err(Error::Property {
                            key: key.into(),
                            value: value.into(),
                            reason: "accel|pressure|mic".into(),
                        })
                    }
                }
            }
            "rate" => self.rate = parse_f64(key, value)?,
            "num-buffers" => self.num_buffers = Some(parse_usize(key, value)? as u64),
            "is-live" => self.is_live = value == "true" || value == "1",
            "window" => self.window = parse_usize(key, value)?,
            "channels" => self.channels = parse_usize(key, value)?,
            "seed" => self.seed = parse_usize(key, value)? as u64,
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of sensorsrc".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        // layout is [sample][channel]: channels vary fastest -> minor-first dims
        let info = TensorInfo::new(DType::F32, Dims::new(&[self.channels, self.window]));
        Ok(vec![
            Caps::Tensor {
                info,
                fps_millis: (self.rate * 1000.0) as u64,
            };
            n_srcs.max(1)
        ])
    }

    fn propose_caps(&mut self, downstream: &Caps) -> Result<()> {
        if let Caps::Tensor { info, fps_millis } = downstream {
            if info.dtype == DType::F32 && info.dims.effective_rank() <= 2 {
                self.channels = info.dims.dim_or_1(0);
                self.window = info.dims.dim_or_1(1);
                if *fps_millis > 0 {
                    self.rate = *fps_millis as f64 / 1000.0;
                }
            }
        }
        Ok(())
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if let Some(max) = self.num_buffers {
            if self.n >= max {
                return Ok(Flow::Eos);
            }
        }
        let dur_ns = (1e9 / self.rate.max(0.001)) as u64;
        let pts = self.n * dur_ns;
        if self.is_live {
            ctx.sleep_until_pts(pts);
            if ctx.stopped() {
                return Ok(Flow::Eos);
            }
        }
        let t_window = 1.0 / self.rate.max(0.001);
        let mut data = vec![0f32; self.window * self.channels];
        for s in 0..self.window {
            let t = self.n as f64 * t_window + s as f64 * t_window / self.window as f64;
            for c in 0..self.channels {
                data[s * self.channels + c] =
                    self.sample(t, c, self.n * self.window as u64 + s as u64);
            }
        }
        let mut buf = Buffer::from_f32(pts, &data);
        buf.duration_ns = dur_ns;
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

/// Reads a file and emits it as fixed-size binary frames.
/// Properties: `location`, `blocksize` (bytes per buffer; 0 = whole file).
pub struct FileSrc {
    location: String,
    blocksize: usize,
    data: Option<Arc<Vec<u8>>>,
    offset: usize,
    n: u64,
}

impl FileSrc {
    pub fn new() -> Self {
        Self {
            location: String::new(),
            blocksize: 0,
            data: None,
            offset: 0,
            n: 0,
        }
    }
}

impl Default for FileSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for FileSrc {
    fn type_name(&self) -> &'static str {
        "filesrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "location" => self.location = value.to_string(),
            "blocksize" => self.blocksize = parse_usize(key, value)?,
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of filesrc".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        if self.location.is_empty() {
            return Err(Error::Negotiation("filesrc needs location=".into()));
        }
        Ok(vec![Caps::Any; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if self.data.is_none() {
            self.data = Some(Arc::new(std::fs::read(&self.location)?));
        }
        let data = self.data.as_ref().unwrap().clone();
        if self.offset >= data.len() {
            return Ok(Flow::Eos);
        }
        let end = if self.blocksize == 0 {
            data.len()
        } else {
            (self.offset + self.blocksize).min(data.len())
        };
        let chunk = Chunk::from_vec(data[self.offset..end].to_vec());
        self.offset = end;
        let mut buf = Buffer::single(0, chunk);
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

pub(crate) fn parse_usize(key: &str, value: &str) -> Result<usize> {
    value.parse().map_err(|_| Error::Property {
        key: key.into(),
        value: value.into(),
        reason: "expected integer".into(),
    })
}

pub(crate) fn parse_f64(key: &str, value: &str) -> Result<f64> {
    value.parse().map_err(|_| Error::Property {
        key: key.into(),
        value: value.into(),
        reason: "expected number".into(),
    })
}
