//! Stream-synchronization policies for merging elements (§III).
//!
//! When N tensor streams meet (tensor_mux / tensor_merge), their rates may
//! differ. The paper defines three policies:
//! * **slowest** — emit at the slowest input's rate, dropping frames of
//!   faster sources;
//! * **fastest** — emit at the fastest input's rate, duplicating frames of
//!   slower sources;
//! * **base(k)** — keep the rate of designated input `k`.
//!
//! All merging elements stamp outputs with the *latest* input timestamp.

use std::collections::VecDeque;

use crate::error::{Error, Result};
use crate::tensor::Buffer;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    Slowest,
    Fastest,
    /// Base pad index.
    Base(usize),
}

impl SyncPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        if s == "slowest" {
            return Ok(SyncPolicy::Slowest);
        }
        if s == "fastest" {
            return Ok(SyncPolicy::Fastest);
        }
        if let Some(k) = s.strip_prefix("base:") {
            return Ok(SyncPolicy::Base(k.parse().map_err(|_| {
                Error::Parse(format!("bad base pad in sync policy {s:?}"))
            })?));
        }
        if s == "base" {
            return Ok(SyncPolicy::Base(0));
        }
        Err(Error::Parse(format!("unknown sync policy {s:?}")))
    }
}

/// Per-pad buffering + policy evaluation shared by mux and merge.
pub struct Synchronizer {
    policy: SyncPolicy,
    pads: Vec<PadState>,
}

struct PadState {
    queue: VecDeque<Buffer>,
    /// Most recent buffer ever seen (for `fastest` duplication).
    last: Option<Buffer>,
    eos: bool,
}

impl Synchronizer {
    pub fn new(policy: SyncPolicy, n_pads: usize) -> Self {
        Self {
            policy,
            pads: (0..n_pads)
                .map(|_| PadState {
                    queue: VecDeque::new(),
                    last: None,
                    eos: false,
                })
                .collect(),
        }
    }

    pub fn n_pads(&self) -> usize {
        self.pads.len()
    }

    pub fn push(&mut self, pad: usize, buf: Buffer) {
        let st = &mut self.pads[pad];
        st.last = Some(buf.clone());
        st.queue.push_back(buf);
        // bound growth: a pad racing far ahead keeps only recent frames
        // (its older frames would be dropped by any policy anyway)
        while st.queue.len() > 64 {
            st.queue.pop_front();
        }
    }

    pub fn set_eos(&mut self, pad: usize) {
        self.pads[pad].eos = true;
    }

    pub fn all_eos(&self) -> bool {
        self.pads.iter().all(|p| p.eos)
    }

    /// Try to emit one synchronized set of buffers (one per pad).
    /// Returns `None` until the policy can produce a complete set.
    pub fn try_collect(&mut self) -> Option<Vec<Buffer>> {
        match self.policy {
            SyncPolicy::Slowest => {
                // need at least one queued frame on every live pad
                if self
                    .pads
                    .iter()
                    .any(|p| p.queue.is_empty() && !p.eos)
                {
                    return None;
                }
                if self.pads.iter().any(|p| p.queue.is_empty()) {
                    // some pad is EOS with nothing queued: no complete sets anymore
                    return None;
                }
                // emit the oldest set: pop one from each, dropping any
                // extra queued frames of faster pads beyond the newest
                let target_pts = self
                    .pads
                    .iter()
                    .map(|p| p.queue.front().unwrap().pts_ns)
                    .max()
                    .unwrap();
                let mut set = Vec::with_capacity(self.pads.len());
                for p in &mut self.pads {
                    // drop frames older than the slowest pad's current frame
                    while p.queue.len() > 1 && p.queue[1].pts_ns <= target_pts {
                        p.queue.pop_front();
                    }
                    set.push(p.queue.pop_front().unwrap());
                }
                Some(set)
            }
            SyncPolicy::Fastest => {
                // emit whenever any pad has a fresh frame, duplicating the
                // latest frame of the others; wait until all pads have seen
                // at least one frame
                if self.pads.iter().any(|p| p.last.is_none()) {
                    // drain queues (they are retained in `last`)
                    for p in &mut self.pads {
                        p.queue.clear();
                    }
                    return None;
                }
                let any_fresh = self.pads.iter().any(|p| !p.queue.is_empty());
                if !any_fresh {
                    return None;
                }
                let mut set = Vec::with_capacity(self.pads.len());
                for p in &mut self.pads {
                    if let Some(b) = p.queue.pop_front() {
                        set.push(b);
                    } else {
                        set.push(p.last.clone().unwrap());
                    }
                }
                // clear any remaining backlog beyond one frame per round
                Some(set)
            }
            SyncPolicy::Base(k) => {
                let k = k.min(self.pads.len() - 1);
                if self.pads[k].queue.is_empty() {
                    return None;
                }
                if self.pads.iter().any(|p| p.last.is_none()) {
                    return None;
                }
                let base = self.pads[k].queue.pop_front().unwrap();
                let base_pts = base.pts_ns;
                let mut set = Vec::with_capacity(self.pads.len());
                for (i, p) in self.pads.iter_mut().enumerate() {
                    if i == k {
                        set.push(base.clone());
                        continue;
                    }
                    // take the newest frame not newer than base (or the
                    // closest available)
                    while p.queue.len() > 1 && p.queue[1].pts_ns <= base_pts {
                        p.queue.pop_front();
                    }
                    if let Some(front) = p.queue.front() {
                        if front.pts_ns <= base_pts {
                            let b = p.queue.pop_front().unwrap();
                            p.last = Some(b.clone());
                            set.push(b);
                            continue;
                        }
                    }
                    set.push(p.last.clone().unwrap());
                }
                Some(set)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(pts: u64, v: f32) -> Buffer {
        Buffer::from_f32(pts, &[v])
    }

    #[test]
    fn parse_policies() {
        assert_eq!(SyncPolicy::parse("slowest").unwrap(), SyncPolicy::Slowest);
        assert_eq!(SyncPolicy::parse("fastest").unwrap(), SyncPolicy::Fastest);
        assert_eq!(SyncPolicy::parse("base:2").unwrap(), SyncPolicy::Base(2));
        assert!(SyncPolicy::parse("warpspeed").is_err());
    }

    #[test]
    fn slowest_waits_for_all() {
        let mut s = Synchronizer::new(SyncPolicy::Slowest, 2);
        s.push(0, buf(0, 1.0));
        assert!(s.try_collect().is_none());
        s.push(1, buf(0, 2.0));
        let set = s.try_collect().unwrap();
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn slowest_drops_fast_pad_backlog() {
        let mut s = Synchronizer::new(SyncPolicy::Slowest, 2);
        // pad 0 is fast: 4 frames at pts 0,10,20,30; pad 1 slow: one at 25
        for (pts, v) in [(0, 0.0), (10, 1.0), (20, 2.0), (30, 3.0)] {
            s.push(0, buf(pts, v));
        }
        s.push(1, buf(25, 9.0));
        let set = s.try_collect().unwrap();
        // fast pad should have skipped to pts 20 (newest <= 25)
        assert_eq!(set[0].pts_ns, 20);
        assert_eq!(set[1].pts_ns, 25);
    }

    #[test]
    fn fastest_duplicates_slow_pad() {
        let mut s = Synchronizer::new(SyncPolicy::Fastest, 2);
        s.push(0, buf(0, 1.0));
        s.push(1, buf(0, 2.0));
        let _ = s.try_collect().unwrap();
        // only pad 0 gets a new frame; pad 1's last frame is duplicated
        s.push(0, buf(10, 1.5));
        let set = s.try_collect().unwrap();
        assert_eq!(set[0].pts_ns, 10);
        assert_eq!(set[1].pts_ns, 0, "slow pad duplicated");
    }

    #[test]
    fn base_keeps_designated_rate() {
        let mut s = Synchronizer::new(SyncPolicy::Base(0), 2);
        // base pad at 10 Hz, other at 30 Hz
        s.push(1, buf(0, 0.0));
        s.push(1, buf(3, 0.1));
        s.push(1, buf(6, 0.2));
        assert!(s.try_collect().is_none(), "waits for base pad");
        s.push(0, buf(5, 1.0));
        let set = s.try_collect().unwrap();
        assert_eq!(set[0].pts_ns, 5);
        // newest non-base frame with pts <= 5 is pts 3
        assert_eq!(set[1].pts_ns, 3);
    }
}
