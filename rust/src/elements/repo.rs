//! `tensor_repo_src` / `tensor_repo_sink`: recurrence without stream
//! cycles (§III).
//!
//! GStreamer (and our graph) prohibits cycles. A repo-sink stores each
//! frame into a named slot; a repo-src emits the most recent frame of that
//! slot (or a configured initial value before anything arrives), paced at
//! its own rate. This is how NNStreamer expresses recurrent paths
//! (LSTM state, detection feedback like E4's FlowLimiter cycle).

use std::collections::HashMap;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::element::props::{parse_bool, unknown_property};
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, DType, Dims, TensorInfo};

use super::sources::{parse_f64, parse_usize};

/// Global named-slot repository shared by all pipelines in the process.
static REPO: Lazy<Mutex<HashMap<String, Buffer>>> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Store a frame into a named repo slot (used by tests and applications).
pub fn repo_store(slot: &str, buf: Buffer) {
    REPO.lock().unwrap().insert(slot.to_string(), buf);
}

/// Fetch the current frame of a slot.
pub fn repo_fetch(slot: &str) -> Option<Buffer> {
    REPO.lock().unwrap().get(slot).cloned()
}

/// Clear a slot (benches reset state between runs).
pub fn repo_clear(slot: &str) {
    REPO.lock().unwrap().remove(slot);
}

/// Typed properties of [`TensorRepoSink`].
#[derive(Debug, Clone, Default)]
pub struct TensorRepoSinkProps {
    /// Repository slot to publish into (`slot`, required).
    pub slot: String,
}

impl Props for TensorRepoSinkProps {
    const FACTORY: &'static str = "tensor_repo_sink";
    const KEYS: &'static [&'static str] = &["slot"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "slot" => self.slot = value.to_string(),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorRepoSink::from_props(self)?))
    }
}

/// Terminal sink that publishes every frame into its named slot.
pub struct TensorRepoSink {
    props: TensorRepoSinkProps,
}

impl TensorRepoSink {
    pub fn new() -> Self {
        Self::from_props(TensorRepoSinkProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorRepoSink {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorRepoSink {
    type Props = TensorRepoSinkProps;

    fn from_props(props: TensorRepoSinkProps) -> Result<Self> {
        Ok(Self { props })
    }
}

impl Element for TensorRepoSink {
    fn type_name(&self) -> &'static str {
        "tensor_repo_sink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        if self.props.slot.is_empty() {
            return Err(Error::Negotiation("tensor_repo_sink needs slot=".into()));
        }
        Ok(vec![])
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            repo_store(&self.props.slot, buf);
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`TensorRepoSrc`]. The `info` describes the slot's
/// tensors for negotiation and the zero-filled initial frame emitted
/// before the slot is first written (`dimension=`/`type=` in string form).
#[derive(Debug, Clone)]
pub struct TensorRepoSrcProps {
    /// Repository slot to read (`slot`, required).
    pub slot: String,
    /// Emission rate, frames/s (`rate`).
    pub rate: f64,
    pub num_buffers: Option<u64>,
    pub is_live: bool,
    /// Tensor layout of the slot (`dimension` + `type`).
    pub info: Option<TensorInfo>,
}

impl Default for TensorRepoSrcProps {
    fn default() -> Self {
        Self {
            slot: String::new(),
            rate: 30.0,
            num_buffers: None,
            is_live: true,
            info: None,
        }
    }
}

impl Props for TensorRepoSrcProps {
    const FACTORY: &'static str = "tensor_repo_src";
    const KEYS: &'static [&'static str] =
        &["slot", "rate", "num-buffers", "is-live", "dimension", "type"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "slot" => self.slot = value.to_string(),
            "rate" => self.rate = parse_f64(key, value)?,
            "num-buffers" => self.num_buffers = Some(parse_usize(key, value)? as u64),
            "is-live" => self.is_live = parse_bool(value),
            "dimension" => {
                let dims = Dims::parse(value)?;
                let dtype = self.info.as_ref().map(|i| i.dtype).unwrap_or(DType::F32);
                self.info = Some(TensorInfo::new(dtype, dims));
            }
            "type" => {
                let dtype = DType::parse(value)?;
                let dims = self
                    .info
                    .as_ref()
                    .map(|i| i.dims.clone())
                    .unwrap_or_else(|| Dims::new(&[1]));
                self.info = Some(TensorInfo::new(dtype, dims));
            }
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorRepoSrc::from_props(self)?))
    }
}

/// Source that emits the latest frame of its slot at a fixed rate.
pub struct TensorRepoSrc {
    props: TensorRepoSrcProps,
    n: u64,
}

impl TensorRepoSrc {
    pub fn new() -> Self {
        Self::from_props(TensorRepoSrcProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorRepoSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorRepoSrc {
    type Props = TensorRepoSrcProps;

    fn from_props(props: TensorRepoSrcProps) -> Result<Self> {
        Ok(Self { props, n: 0 })
    }
}

impl Element for TensorRepoSrc {
    fn type_name(&self) -> &'static str {
        "tensor_repo_src"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        if self.props.slot.is_empty() {
            return Err(Error::Negotiation("tensor_repo_src needs slot=".into()));
        }
        let info = self
            .props
            .info
            .clone()
            .ok_or_else(|| Error::Negotiation("tensor_repo_src needs dimension=/type=".into()))?;
        Ok(vec![
            Caps::Tensor {
                info,
                fps_millis: (self.props.rate * 1000.0) as u64
            };
            n_srcs.max(1)
        ])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!()
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        if let Some(max) = self.props.num_buffers {
            if self.n >= max {
                return Ok(Flow::Eos);
            }
        }
        let dur = (1e9 / self.props.rate.max(0.001)) as u64;
        let pts = self.n * dur;
        if self.props.is_live {
            if ctx.stopped() {
                return Ok(Flow::Eos);
            }
            if ctx.park_until_pts(pts) {
                return Ok(Flow::Wait);
            }
        }
        let mut buf = match repo_fetch(&self.props.slot) {
            Some(mut b) => {
                b.pts_ns = pts;
                b
            }
            None => {
                // initial zero frame
                let info = self.props.info.as_ref().unwrap();
                Buffer::single(pts, Chunk::from_vec(vec![0u8; info.size_bytes()]))
            }
        };
        buf.seq = self.n;
        self.n += 1;
        ctx.push(0, buf)?;
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repo_roundtrip() {
        repo_clear("t");
        assert!(repo_fetch("t").is_none());
        repo_store("t", Buffer::from_f32(5, &[1.0, 2.0]));
        let b = repo_fetch("t").unwrap();
        assert_eq!(b.chunk().as_f32().unwrap(), &[1.0, 2.0]);
        repo_clear("t");
    }

    #[test]
    fn recurrence_through_pipeline() {
        use crate::pipeline::Pipeline;
        repo_clear("rec");
        // writer pipeline: sensor windows -> repo slot "rec"
        let mut p = Pipeline::parse(
            "sensorsrc num-buffers=5 window=4 channels=1 rate=100 ! \
             tensor_repo_sink slot=rec",
        )
        .unwrap();
        p.run().unwrap();
        assert!(repo_fetch("rec").is_some());

        // reader pipeline: repo src replays the last stored frame
        let mut p2 = Pipeline::parse(
            "tensor_repo_src slot=rec dimension=4:1 type=float32 rate=1000 \
             num-buffers=3 is-live=false ! fakesink name=out",
        )
        .unwrap();
        let report = p2.run().unwrap();
        assert_eq!(report.element("out").unwrap().buffers_in(), 3);
        repo_clear("rec");
    }
}
