//! Stream-flow utilities: `queue`, `tee`, `valve`, `capsfilter`,
//! `input-selector`, `output-selector`.
//!
//! These are the "dynamic flow control" components the paper lists as
//! product requirements (§III): valves and selectors let application
//! threads steer flows — before start through the shared control handles
//! ([`Valve::control`], [`InputSelector::control`]), and on a playing
//! pipeline through the scheduler's control channel
//! ([`Running::set_valve`], [`Running::select_input`],
//! [`Running::select_output`]); `tensor_if` (see [`super::tensor_if`])
//! steers on tensor values without application involvement.
//!
//! [`Running::set_valve`]: crate::pipeline::Running::set_valve
//! [`Running::select_input`]: crate::pipeline::Running::select_input
//! [`Running::select_output`]: crate::pipeline::Running::select_output

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::element::props::{parse_bool, unknown_property};
use crate::element::{Ctx, Delivery, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::Result;
use crate::tensor::Caps;

use super::sources::parse_usize;

/// Typed properties of [`Queue`].
#[derive(Debug, Clone)]
pub struct QueueProps {
    /// Input-channel capacity (`max-size-buffers`, default 16).
    pub max_size_buffers: usize,
    /// Drop new buffers when full instead of blocking the producer
    /// (`leaky=downstream`).
    pub leaky: bool,
}

impl Default for QueueProps {
    fn default() -> Self {
        Self {
            max_size_buffers: 16,
            leaky: false,
        }
    }
}

impl Props for QueueProps {
    const FACTORY: &'static str = "queue";
    const KEYS: &'static [&'static str] = &["max-size-buffers", "leaky"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "max-size-buffers" => self.max_size_buffers = parse_usize(key, value)?.max(1),
            "leaky" => self.leaky = value == "downstream" || value == "true" || value == "2",
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(Queue::from_props(self)?))
    }
}

/// Decouples producer from consumer by raising the capacity of its
/// bounded input inbox (under the pooled executor a saturated inbox
/// parks the producer's task instead of blocking a thread — same
/// backpressure, no thread held).
pub struct Queue {
    props: QueueProps,
}

impl Queue {
    pub fn new() -> Self {
        Self {
            props: QueueProps::default(),
        }
    }
}

impl Default for Queue {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for Queue {
    type Props = QueueProps;

    fn from_props(mut props: QueueProps) -> Result<Self> {
        // same clamp as the string front-end: capacity is at least 1
        props.max_size_buffers = props.max_size_buffers.max(1);
        Ok(Self { props })
    }
}

impl Element for Queue {
    fn type_name(&self) -> &'static str {
        "queue"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn preferred_input_capacity(&self) -> usize {
        self.props.max_size_buffers
    }

    fn input_delivery(&self) -> Delivery {
        if self.props.leaky {
            Delivery::Leaky
        } else {
            Delivery::Blocking
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            ctx.push(0, buf)?;
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`Tee`] (none).
#[derive(Debug, Clone, Copy, Default)]
pub struct TeeProps;

impl Props for TeeProps {
    const FACTORY: &'static str = "tee";
    const KEYS: &'static [&'static str] = &[];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        Err(unknown_property(Self::FACTORY, Self::KEYS, key, value))
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(Tee::from_props(self)?))
    }
}

/// Fans one stream out to N branches (buffers are shared, not copied:
/// chunks are refcounted).
pub struct Tee;

impl Tee {
    pub fn new() -> Self {
        Tee
    }
}

impl Default for Tee {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for Tee {
    type Props = TeeProps;

    fn from_props(_props: TeeProps) -> Result<Self> {
        Ok(Tee)
    }
}

impl Element for Tee {
    fn type_name(&self) -> &'static str {
        "tee"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 64 }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let n = ctx.n_src_pads();
            for pad in 0..n {
                ctx.push(pad, buf.clone())?;
            }
        }
        Ok(Flow::Continue)
    }
}

/// Shared on/off switch usable from application threads.
#[derive(Clone, Default)]
pub struct ValveControl(Arc<AtomicBool>);

impl ValveControl {
    pub fn set_open(&self, open: bool) {
        self.0.store(open, Ordering::Relaxed);
    }

    pub fn is_open(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Typed properties of [`Valve`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ValveProps {
    /// Start in the dropping state (`drop=true`; default passes).
    pub drop: bool,
}

impl Props for ValveProps {
    const FACTORY: &'static str = "valve";
    const KEYS: &'static [&'static str] = &["drop"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "drop" => self.drop = parse_bool(value),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(Valve::from_props(self)?))
    }
}

/// Drops all buffers while closed. Switch at runtime with
/// [`Valve::control`] (pre-start handle) or
/// [`Running::set_valve`](crate::pipeline::Running::set_valve)
/// (control channel of a playing pipeline).
pub struct Valve {
    control: ValveControl,
}

impl Valve {
    pub fn new() -> Self {
        Self::from_props(ValveProps::default()).expect("defaults are valid")
    }

    pub fn control(&self) -> ValveControl {
        self.control.clone()
    }
}

impl Default for Valve {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for Valve {
    type Props = ValveProps;

    fn from_props(props: ValveProps) -> Result<Self> {
        let control = ValveControl::default();
        control.set_open(!props.drop);
        Ok(Self { control })
    }
}

impl Element for Valve {
    fn type_name(&self) -> &'static str {
        "valve"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        let mut props = ValveProps {
            drop: !self.control.is_open(),
        };
        props.set(key, value)?;
        self.control.set_open(!props.drop);
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            if self.control.is_open() {
                ctx.push(0, buf)?;
            } else {
                ctx.stats().record_drop();
            }
        }
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`CapsFilter`].
#[derive(Debug, Clone)]
pub struct CapsFilterProps {
    /// The restriction imposed on the link.
    pub caps: Caps,
}

impl Default for CapsFilterProps {
    fn default() -> Self {
        Self { caps: Caps::Any }
    }
}

impl Props for CapsFilterProps {
    const FACTORY: &'static str = "capsfilter";
    const KEYS: &'static [&'static str] = &["caps"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "caps" => self.caps = Caps::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(CapsFilter::from_props(self)?))
    }
}

/// Restricts caps on a link (`video/x-raw,format=RGB,...` in launch syntax).
pub struct CapsFilter {
    props: CapsFilterProps,
}

impl CapsFilter {
    pub fn new() -> Self {
        Self {
            props: CapsFilterProps::default(),
        }
    }
}

impl Default for CapsFilter {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for CapsFilter {
    type Props = CapsFilterProps;

    fn from_props(props: CapsFilterProps) -> Result<Self> {
        Ok(Self { props })
    }
}

impl Element for CapsFilter {
    fn type_name(&self) -> &'static str {
        "capsfilter"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let fixed = in_caps[0].intersect(&self.props.caps)?;
        Ok(vec![fixed; n_srcs.max(1)])
    }

    fn proposed_caps(&self) -> Option<Caps> {
        if self.props.caps == Caps::Any {
            None
        } else {
            Some(self.props.caps.clone())
        }
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            ctx.push(0, buf)?;
        }
        Ok(Flow::Continue)
    }
}

/// Shared pad selector for input-/output-selector.
#[derive(Clone, Default)]
pub struct SelectorControl(Arc<AtomicUsize>);

impl SelectorControl {
    pub fn select(&self, pad: usize) {
        self.0.store(pad, Ordering::Relaxed);
    }

    pub fn selected(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

/// Typed properties of [`InputSelector`].
#[derive(Debug, Clone, Copy, Default)]
pub struct InputSelectorProps {
    /// Initially active pad (`active-pad`).
    pub active_pad: usize,
}

impl Props for InputSelectorProps {
    const FACTORY: &'static str = "input-selector";
    const KEYS: &'static [&'static str] = &["active-pad"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "active-pad" => self.active_pad = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(InputSelector::from_props(self)?))
    }
}

/// Typed properties of [`OutputSelector`].
#[derive(Debug, Clone, Copy, Default)]
pub struct OutputSelectorProps {
    /// Initially active pad (`active-pad`).
    pub active_pad: usize,
}

impl Props for OutputSelectorProps {
    const FACTORY: &'static str = "output-selector";
    const KEYS: &'static [&'static str] = &["active-pad"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "active-pad" => self.active_pad = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(OutputSelector::from_props(self)?))
    }
}

/// N inputs, 1 output: forwards only the active input pad.
pub struct InputSelector {
    control: SelectorControl,
}

impl InputSelector {
    pub fn new() -> Self {
        Self::from_props(InputSelectorProps::default()).expect("defaults are valid")
    }

    pub fn control(&self) -> SelectorControl {
        self.control.clone()
    }
}

impl Default for InputSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for InputSelector {
    type Props = InputSelectorProps;

    fn from_props(props: InputSelectorProps) -> Result<Self> {
        let control = SelectorControl::default();
        control.select(props.active_pad);
        Ok(Self { control })
    }
}

impl Element for InputSelector {
    fn type_name(&self) -> &'static str {
        "input-selector"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 16 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        let mut props = InputSelectorProps {
            active_pad: self.control.selected(),
        };
        props.set(key, value)?;
        self.control.select(props.active_pad);
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        // all inputs must be mutually compatible
        for c in in_caps.iter().skip(1) {
            if !in_caps[0].compatible(c) {
                return Err(crate::error::Error::Negotiation(format!(
                    "input-selector inputs disagree: {} vs {}",
                    in_caps[0], c
                )));
            }
        }
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            if pad == self.control.selected() {
                ctx.push(0, buf)?;
            } else {
                ctx.stats().record_drop();
            }
        }
        Ok(Flow::Continue)
    }
}

/// 1 input, N outputs: forwards to the active output pad only.
pub struct OutputSelector {
    control: SelectorControl,
}

impl OutputSelector {
    pub fn new() -> Self {
        Self::from_props(OutputSelectorProps::default()).expect("defaults are valid")
    }

    pub fn control(&self) -> SelectorControl {
        self.control.clone()
    }
}

impl Default for OutputSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for OutputSelector {
    type Props = OutputSelectorProps;

    fn from_props(props: OutputSelectorProps) -> Result<Self> {
        let control = SelectorControl::default();
        control.select(props.active_pad);
        Ok(Self { control })
    }
}

impl Element for OutputSelector {
    fn type_name(&self) -> &'static str {
        "output-selector"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 16 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        let mut props = OutputSelectorProps {
            active_pad: self.control.selected(),
        };
        props.set(key, value)?;
        self.control.select(props.active_pad);
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let sel = self.control.selected().min(ctx.n_src_pads().saturating_sub(1));
            ctx.push(sel, buf)?;
        }
        Ok(Flow::Continue)
    }
}
