//! Stream-flow utilities: `queue`, `tee`, `valve`, `capsfilter`,
//! `input-selector`, `output-selector`.
//!
//! These are the "dynamic flow control" components the paper lists as
//! product requirements (§III): valves and selectors let application
//! threads steer flows; `tensor_if` (see [`super::tensor_if`]) steers on
//! tensor values without application involvement.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::element::{Ctx, Delivery, Element, Flow, Item, PadSpec};
use crate::error::{Error, Result};
use crate::tensor::Caps;

use super::sources::parse_usize;

/// Decouples producer from consumer by raising the input-channel capacity.
/// Properties: `max-size-buffers` (default 16), `leaky` (drop when full).
pub struct Queue {
    capacity: usize,
    leaky: bool,
}

impl Queue {
    pub fn new() -> Self {
        Self {
            capacity: 16,
            leaky: false,
        }
    }
}

impl Default for Queue {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for Queue {
    fn type_name(&self) -> &'static str {
        "queue"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "max-size-buffers" => self.capacity = parse_usize(key, value)?.max(1),
            "leaky" => self.leaky = value == "downstream" || value == "true" || value == "2",
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of queue".into(),
                })
            }
        }
        Ok(())
    }

    fn preferred_input_capacity(&self) -> usize {
        self.capacity
    }

    fn input_delivery(&self) -> Delivery {
        if self.leaky {
            Delivery::Leaky
        } else {
            Delivery::Blocking
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            ctx.push(0, buf)?;
        }
        Ok(Flow::Continue)
    }
}

/// Fans one stream out to N branches (buffers are shared, not copied:
/// chunks are refcounted).
pub struct Tee;

impl Tee {
    pub fn new() -> Self {
        Tee
    }
}

impl Default for Tee {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for Tee {
    fn type_name(&self) -> &'static str {
        "tee"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 64 }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let n = ctx.n_src_pads();
            for pad in 0..n {
                ctx.push(pad, buf.clone())?;
            }
        }
        Ok(Flow::Continue)
    }
}

/// Shared on/off switch usable from application threads.
#[derive(Clone, Default)]
pub struct ValveControl(Arc<AtomicBool>);

impl ValveControl {
    pub fn set_open(&self, open: bool) {
        self.0.store(open, Ordering::Relaxed);
    }

    pub fn is_open(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Drops all buffers while closed. Properties: `drop` (initial state,
/// `true` = dropping). Use [`Valve::control`] for runtime switching.
pub struct Valve {
    control: ValveControl,
}

impl Valve {
    pub fn new() -> Self {
        let control = ValveControl::default();
        control.set_open(true);
        Self { control }
    }

    pub fn control(&self) -> ValveControl {
        self.control.clone()
    }
}

impl Default for Valve {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for Valve {
    fn type_name(&self) -> &'static str {
        "valve"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "drop" => {
                self.control.set_open(!(value == "true" || value == "1"));
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of valve".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            if self.control.is_open() {
                ctx.push(0, buf)?;
            } else {
                ctx.stats().record_drop();
            }
        }
        Ok(Flow::Continue)
    }
}

/// Restricts caps on a link (`video/x-raw,format=RGB,...` in launch syntax).
pub struct CapsFilter {
    caps: Caps,
}

impl CapsFilter {
    pub fn new() -> Self {
        Self { caps: Caps::Any }
    }
}

impl Default for CapsFilter {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for CapsFilter {
    fn type_name(&self) -> &'static str {
        "capsfilter"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "caps" => {
                self.caps = Caps::parse(value)?;
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of capsfilter".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let fixed = in_caps[0].intersect(&self.caps)?;
        Ok(vec![fixed; n_srcs.max(1)])
    }

    fn proposed_caps(&self) -> Option<Caps> {
        if self.caps == Caps::Any {
            None
        } else {
            Some(self.caps.clone())
        }
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            ctx.push(0, buf)?;
        }
        Ok(Flow::Continue)
    }
}

/// Shared pad selector for input-/output-selector.
#[derive(Clone, Default)]
pub struct SelectorControl(Arc<AtomicUsize>);

impl SelectorControl {
    pub fn select(&self, pad: usize) {
        self.0.store(pad, Ordering::Relaxed);
    }

    pub fn selected(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

/// N inputs, 1 output: forwards only the active input pad.
pub struct InputSelector {
    control: SelectorControl,
}

impl InputSelector {
    pub fn new() -> Self {
        Self {
            control: SelectorControl::default(),
        }
    }

    pub fn control(&self) -> SelectorControl {
        self.control.clone()
    }
}

impl Default for InputSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for InputSelector {
    fn type_name(&self) -> &'static str {
        "input-selector"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 16 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "active-pad" => {
                self.control.select(parse_usize(key, value)?);
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of input-selector".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        // all inputs must be mutually compatible
        for c in in_caps.iter().skip(1) {
            if !in_caps[0].compatible(c) {
                return Err(Error::Negotiation(format!(
                    "input-selector inputs disagree: {} vs {}",
                    in_caps[0], c
                )));
            }
        }
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            if pad == self.control.selected() {
                ctx.push(0, buf)?;
            } else {
                ctx.stats().record_drop();
            }
        }
        Ok(Flow::Continue)
    }
}

/// 1 input, N outputs: forwards to the active output pad only.
pub struct OutputSelector {
    control: SelectorControl,
}

impl OutputSelector {
    pub fn new() -> Self {
        Self {
            control: SelectorControl::default(),
        }
    }

    pub fn control(&self) -> SelectorControl {
        self.control.clone()
    }
}

impl Default for OutputSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for OutputSelector {
    fn type_name(&self) -> &'static str {
        "output-selector"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Variadic { max: 16 }
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "active-pad" => {
                self.control.select(parse_usize(key, value)?);
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of output-selector".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        if let Item::Buffer(buf) = item {
            let sel = self.control.selected().min(ctx.n_src_pads().saturating_sub(1));
            ctx.push(sel, buf)?;
        }
        Ok(Flow::Continue)
    }
}
