//! `tensor_decoder`: tensor streams → media/other streams (§III).
//!
//! Sub-plugins (property `mode=`):
//! * `image_labeling` — classifier probs → text label index stream
//! * `bounding_boxes` — detector raw output → framed box list
//!   (`option1=yolo|ssd` selects the head layout; thresholds via option2)
//! * `direct_video` — tensor → RGB overlay frame (transparent background
//!   with detection boxes, as in Fig 1)
//! * `flatbuf` — framed binary serialization of the tensors (the paper's
//!   Flatbuf/Protobuf interconnection for heterogeneous pipelines)

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, DType, Dims, TensorInfo, VideoFormat, VideoInfo};

use super::sources::{parse_f64, parse_usize};

/// Decoder sub-plugin selection (`mode=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecoderMode {
    #[default]
    ImageLabeling,
    BoundingBoxes,
    DirectVideo,
    FlatBuf,
}

impl DecoderMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "image_labeling" => DecoderMode::ImageLabeling,
            "bounding_boxes" => DecoderMode::BoundingBoxes,
            "direct_video" => DecoderMode::DirectVideo,
            "flatbuf" => DecoderMode::FlatBuf,
            _ => {
                return Err(Error::Property {
                    key: "mode".into(),
                    value: s.into(),
                    reason: "image_labeling|bounding_boxes|direct_video|flatbuf".into(),
                })
            }
        })
    }
}

/// Typed properties of [`TensorDecoder`].
#[derive(Debug, Clone)]
pub struct TensorDecoderProps {
    /// Sub-plugin (`mode`).
    pub mode: DecoderMode,
    /// Head layout for bounding_boxes: "yolo" or "ssd" (`option1`).
    pub head: String,
    /// Score threshold for bounding_boxes (`option2` / `threshold`).
    pub threshold: f32,
    /// Output canvas width for direct_video (`width`).
    pub width: usize,
    /// Output canvas height for direct_video (`height`).
    pub height: usize,
}

impl Default for TensorDecoderProps {
    fn default() -> Self {
        Self {
            mode: DecoderMode::ImageLabeling,
            head: "ssd".to_string(),
            threshold: 0.5,
            width: 320,
            height: 240,
        }
    }
}

impl Props for TensorDecoderProps {
    const FACTORY: &'static str = "tensor_decoder";
    const KEYS: &'static [&'static str] =
        &["mode", "option1", "option2", "threshold", "width", "height"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "mode" => self.mode = DecoderMode::parse(value)?,
            "option1" => self.head = value.to_string(),
            "option2" | "threshold" => self.threshold = parse_f64(key, value)? as f32,
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorDecoder::from_props(self)?))
    }
}

pub struct TensorDecoder {
    props: TensorDecoderProps,
    in_infos: Vec<TensorInfo>,
}

/// One decoded detection box, serialized into the output tensor stream as
/// 6 f32 values: (x, y, w, h, score, class).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetBox {
    pub x: f32,
    pub y: f32,
    pub w: f32,
    pub h: f32,
    pub score: f32,
    pub class: usize,
}

/// Serialize boxes into a flat f32 chunk (6 per box, prefixed by count).
pub fn encode_boxes(boxes: &[DetBox]) -> Chunk {
    let mut data = Vec::with_capacity(1 + boxes.len() * 6);
    data.push(boxes.len() as f32);
    for b in boxes {
        data.extend_from_slice(&[b.x, b.y, b.w, b.h, b.score, b.class as f32]);
    }
    Chunk::from_f32(&data)
}

/// Parse boxes back from a decoded chunk.
pub fn decode_boxes(chunk: &Chunk) -> Result<Vec<DetBox>> {
    let data = chunk.to_f32_vec()?;
    if data.is_empty() {
        return Ok(vec![]);
    }
    let n = data[0] as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let o = 1 + i * 6;
        if o + 6 > data.len() {
            break;
        }
        out.push(DetBox {
            x: data[o],
            y: data[o + 1],
            w: data[o + 2],
            h: data[o + 3],
            score: data[o + 4],
            class: data[o + 5] as usize,
        });
    }
    Ok(out)
}

/// Max number of boxes the decoder emits per frame (fixed-size stream).
pub const MAX_BOXES: usize = 32;

impl FromProps for TensorDecoder {
    type Props = TensorDecoderProps;

    fn from_props(props: TensorDecoderProps) -> Result<Self> {
        Ok(Self {
            props,
            in_infos: Vec::new(),
        })
    }
}

impl TensorDecoder {
    pub fn new() -> Self {
        Self::from_props(TensorDecoderProps::default()).expect("defaults are valid")
    }

    fn decode_yolo(&self, raw: &[f32], grid: usize, anchors: usize, classes: usize) -> Vec<DetBox> {
        // raw layout: (grid, grid, anchors*(5+classes)) NHWC-flattened
        let stride = anchors * (5 + classes);
        let mut boxes = Vec::new();
        for gy in 0..grid {
            for gx in 0..grid {
                let cell = &raw[(gy * grid + gx) * stride..(gy * grid + gx + 1) * stride];
                for a in 0..anchors {
                    let o = a * (5 + classes);
                    let obj = sigmoid(cell[o + 4]);
                    if obj < self.props.threshold {
                        continue;
                    }
                    let (mut best_c, mut best_p) = (0usize, f32::MIN);
                    for c in 0..classes {
                        if cell[o + 5 + c] > best_p {
                            best_p = cell[o + 5 + c];
                            best_c = c;
                        }
                    }
                    boxes.push(DetBox {
                        x: (gx as f32 + sigmoid(cell[o])) / grid as f32,
                        y: (gy as f32 + sigmoid(cell[o + 1])) / grid as f32,
                        w: cell[o + 2].exp().min(grid as f32) / grid as f32,
                        h: cell[o + 3].exp().min(grid as f32) / grid as f32,
                        score: obj,
                        class: best_c,
                    });
                }
            }
        }
        boxes.truncate(MAX_BOXES);
        boxes
    }

    fn decode_ssd(&self, locs: &[f32], confs: &[f32], n_anchors: usize, classes: usize) -> Vec<DetBox> {
        let mut boxes = Vec::new();
        for i in 0..n_anchors {
            // softmax over classes; class 0 is background
            let c = &confs[i * classes..(i + 1) * classes];
            let m = c.iter().fold(f32::MIN, |a, &b| a.max(b));
            let exps: Vec<f32> = c.iter().map(|&v| (v - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            let (mut best_c, mut best_p) = (0usize, 0.0f32);
            for (ci, &e) in exps.iter().enumerate().skip(1) {
                let p = e / z;
                if p > best_p {
                    best_p = p;
                    best_c = ci;
                }
            }
            if best_p < self.props.threshold {
                continue;
            }
            let l = &locs[i * 4..(i + 1) * 4];
            // anchor grid: row-major square-ish layout in [0,1]
            let side = (n_anchors as f32).sqrt().ceil() as usize;
            let ax = (i % side) as f32 / side as f32;
            let ay = (i / side) as f32 / side as f32;
            boxes.push(DetBox {
                x: (ax + sigmoid(l[0]) / side as f32).clamp(0.0, 1.0),
                y: (ay + sigmoid(l[1]) / side as f32).clamp(0.0, 1.0),
                w: sigmoid(l[2]),
                h: sigmoid(l[3]),
                score: best_p,
                class: best_c,
            });
            if boxes.len() >= MAX_BOXES {
                break;
            }
        }
        boxes
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl Default for TensorDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorDecoder {
    fn type_name(&self) -> &'static str {
        "tensor_decoder"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let (infos, fps) = match &in_caps[0] {
            Caps::Tensor { info, fps_millis } => (vec![info.clone()], *fps_millis),
            Caps::Tensors { infos, fps_millis } => (infos.clone(), *fps_millis),
            other => {
                return Err(Error::Negotiation(format!(
                    "tensor_decoder needs tensor input, got {other}"
                )))
            }
        };
        self.in_infos = infos;
        let out = match self.props.mode {
            DecoderMode::ImageLabeling => Caps::Tensor {
                info: TensorInfo::new(DType::F32, Dims::new(&[2])),
                fps_millis: fps,
            },
            DecoderMode::BoundingBoxes => Caps::Tensor {
                info: TensorInfo::new(DType::F32, Dims::new(&[1 + MAX_BOXES * 6])),
                fps_millis: fps,
            },
            DecoderMode::DirectVideo => Caps::Video(VideoInfo {
                format: VideoFormat::Rgb,
                width: self.props.width,
                height: self.props.height,
                fps_millis: fps,
            }),
            DecoderMode::FlatBuf => Caps::FlatBuf,
        };
        Ok(vec![out; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let out_chunk = match self.props.mode {
            DecoderMode::ImageLabeling => {
                let probs = buf.chunk().to_f32_vec()?;
                let (mut best, mut best_p) = (0usize, f32::MIN);
                for (i, &p) in probs.iter().enumerate() {
                    if p > best_p {
                        best_p = p;
                        best = i;
                    }
                }
                Chunk::from_f32(&[best as f32, best_p])
            }
            DecoderMode::BoundingBoxes => {
                let boxes = match self.props.head.as_str() {
                    "yolo" => {
                        let raw = buf.chunk().to_f32_vec()?;
                        // infer grid from input info: dims minor-first
                        // (ch : gw : gh : 1)
                        let dims = &self.in_infos[0].dims;
                        let grid = dims.dim_or_1(1);
                        let ch = dims.dim_or_1(0);
                        let anchors = 2;
                        let classes = ch / anchors - 5;
                        self.decode_yolo(&raw, grid, anchors, classes)
                    }
                    "ssd" => {
                        if buf.chunks.len() != 2 {
                            return Err(Error::element(
                                "tensor_decoder",
                                "ssd head needs (locs, confs) tensor pair",
                            ));
                        }
                        let locs = buf.chunks[0].to_f32_vec()?;
                        let confs = buf.chunks[1].to_f32_vec()?;
                        let n = locs.len() / 4;
                        let classes = confs.len() / n.max(1);
                        self.decode_ssd(&locs, &confs, n, classes)
                    }
                    other => {
                        return Err(Error::element(
                            "tensor_decoder",
                            format!("unknown box head {other:?}"),
                        ))
                    }
                };
                let mut data = vec![0f32; 1 + MAX_BOXES * 6];
                data[0] = boxes.len().min(MAX_BOXES) as f32;
                for (i, b) in boxes.iter().take(MAX_BOXES).enumerate() {
                    let o = 1 + i * 6;
                    data[o..o + 6]
                        .copy_from_slice(&[b.x, b.y, b.w, b.h, b.score, b.class as f32]);
                }
                Chunk::from_f32(&data)
            }
            DecoderMode::DirectVideo => {
                // render boxes onto a transparent (black) canvas
                let boxes = decode_boxes(buf.chunk())?;
                let mut canvas = vec![0u8; self.props.width * self.props.height * 3];
                for b in &boxes {
                    draw_box(&mut canvas, self.props.width, self.props.height, b);
                }
                Chunk::from_vec(canvas)
            }
            DecoderMode::FlatBuf => {
                // framed binary: [n_tensors][len_i...][payload_i...]
                let mut out: Vec<u8> = Vec::new();
                out.extend((buf.chunks.len() as u32).to_le_bytes());
                for c in &buf.chunks {
                    out.extend((c.len() as u32).to_le_bytes());
                }
                for c in &buf.chunks {
                    out.extend_from_slice(c.as_bytes());
                }
                Chunk::from_vec(out)
            }
        };
        let mut out = Buffer::single(buf.pts_ns, out_chunk);
        out.seq = buf.seq;
        ctx.push(0, out)?;
        Ok(Flow::Continue)
    }
}

fn draw_box(canvas: &mut [u8], w: usize, h: usize, b: &DetBox) {
    let x0 = ((b.x - b.w / 2.0).max(0.0) * w as f32) as usize;
    let x1 = (((b.x + b.w / 2.0).min(1.0)) * w as f32) as usize;
    let y0 = ((b.y - b.h / 2.0).max(0.0) * h as f32) as usize;
    let y1 = (((b.y + b.h / 2.0).min(1.0)) * h as f32) as usize;
    let color = [(40 + b.class * 50 % 200) as u8, 220, 60];
    for x in x0..x1.min(w) {
        for &y in &[y0, y1.saturating_sub(1)] {
            if y < h {
                let o = (y * w + x) * 3;
                canvas[o..o + 3].copy_from_slice(&color);
            }
        }
    }
    for y in y0..y1.min(h) {
        for &x in &[x0, x1.saturating_sub(1)] {
            if x < w {
                let o = (y * w + x) * 3;
                canvas[o..o + 3].copy_from_slice(&color);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::{ctx_with_outputs, drain};

    #[test]
    fn image_labeling_argmax() {
        let mut d = TensorDecoder::new();
        d.set_property("mode", "image_labeling").unwrap();
        let caps = Caps::tensor(DType::F32, [4], 0.0);
        d.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        d.handle(
            0,
            Item::Buffer(Buffer::from_f32(0, &[0.1, 0.7, 0.15, 0.05])),
            &mut ctx,
        )
        .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        let v = out[0].chunk().to_f32_vec().unwrap();
        assert_eq!(v[0], 1.0);
        assert!((v[1] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn boxes_roundtrip() {
        let boxes = vec![
            DetBox {
                x: 0.5,
                y: 0.5,
                w: 0.2,
                h: 0.1,
                score: 0.9,
                class: 3,
            },
            DetBox {
                x: 0.1,
                y: 0.2,
                w: 0.05,
                h: 0.05,
                score: 0.6,
                class: 0,
            },
        ];
        let c = encode_boxes(&boxes);
        let back = decode_boxes(&c).unwrap();
        assert_eq!(back, boxes);
    }

    #[test]
    fn direct_video_draws_something() {
        let mut d = TensorDecoder::new();
        d.set_property("mode", "direct_video").unwrap();
        d.set_property("width", "32").unwrap();
        d.set_property("height", "32").unwrap();
        let caps = Caps::tensor(DType::F32, [7], 0.0);
        d.negotiate(&[caps], 1).unwrap();
        let boxes = vec![DetBox {
            x: 0.5,
            y: 0.5,
            w: 0.5,
            h: 0.5,
            score: 1.0,
            class: 0,
        }];
        let buf = Buffer::single(0, encode_boxes(&boxes));
        let (mut ctx, rxs) = ctx_with_outputs(1);
        d.handle(0, Item::Buffer(buf), &mut ctx).unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        let px = out[0].chunk().as_bytes_unaccounted();
        assert_eq!(px.len(), 32 * 32 * 3);
        assert!(px.iter().any(|&v| v > 0), "box drawn");
    }

    #[test]
    fn flatbuf_framing() {
        let mut d = TensorDecoder::new();
        d.set_property("mode", "flatbuf").unwrap();
        let caps = Caps::tensor(DType::F32, [2], 0.0);
        d.negotiate(&[caps], 1).unwrap();
        let (mut ctx, rxs) = ctx_with_outputs(1);
        d.handle(0, Item::Buffer(Buffer::from_f32(0, &[1.0, 2.0])), &mut ctx)
            .unwrap();
        drop(ctx);
        let out = drain(&rxs[0]);
        let bytes = out[0].chunk().as_bytes_unaccounted();
        assert_eq!(u32::from_le_bytes(bytes[0..4].try_into().unwrap()), 1);
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 8);
    }
}
