//! `tensor_query_serversrc` / `tensor_query_serversink` /
//! `tensor_query_client`: among-device pipeline composition (the
//! follow-up paper's tensor-query elements, arXiv:2201.06026).
//!
//! A pipeline *serves* a stream by ending a chain in
//! `tensor_query_serversink topic=faces`; any number of other pipelines
//! consume it by starting a chain with `tensor_query_serversrc
//! topic=faces`. Topics resolve through a [`Transport`] (only the
//! in-process backend exists today — `transport=inproc` — network
//! backends slot in without element changes), and the link behaves like
//! an in-pipeline link:
//!
//! * **backpressure** — a saturated subscriber queue makes the
//!   publishing element hand its frame back and park
//!   ([`Flow::Wait`]); no pool worker is held while a topic is idle or
//!   saturated;
//! * **EOS** — when the last publisher of a topic reaches end-of-stream,
//!   every subscriber observes EOS exactly as if an upstream element had
//!   finished.
//!
//! `tensor_query_client` is the request/response element: it forwards
//! each input frame to a serving pipeline's request topic and emits the
//! service's reply downstream — SingleShot over a remote pipeline, in
//! stream form. Launch-syntax example (two pipelines):
//!
//! ```text
//! videotestsrc ! tensor_converter ! tensor_query_serversink topic=frames
//! tensor_query_serversrc topic=frames !
//!     other/tensor,dimension=3:640:480,type=uint8,framerate=30 !
//!     tensor_filter model=i3_opt ! tensor_sink
//! ```
//!
//! (`tensor_query_serversrc` adopts the caps of a directly-following
//! capsfilter; with the typed builder, set
//! [`QueryServerSrcProps::caps`] instead. When the publisher pipeline
//! launched first, its advertised caps are used automatically.)
//!
//! [`Transport`]: crate::pipeline::stream::Transport
//! [`Flow::Wait`]: crate::element::Flow::Wait

use std::sync::Arc;

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, PadSpec, Props};
use crate::error::{Error, Fault, Result};
use crate::pipeline::executor::SharedWaker;
use crate::pipeline::stream::{
    transport, PortRecv, PortSend, PublisherPort, Qos, StreamEnd, SubscriberPort,
    DEFAULT_ENDPOINT_CAPACITY,
};
use crate::tensor::Caps;

use super::sources::parse_usize;

/// Typed properties of [`TensorQueryServerSink`].
#[derive(Debug, Clone)]
pub struct QueryServerSinkProps {
    /// Stream topic to publish (`topic`, required).
    pub topic: String,
    /// Delivery backend (`transport`, default `inproc`).
    pub transport: String,
    /// Park until at least this many subscribers are attached instead of
    /// dropping frames while nobody listens (`wait-subscribers`,
    /// default 0 = pub/sub drop semantics).
    pub wait_subscribers: usize,
    /// Publisher-side QoS (`qos`, default `blocking`): `leaky` or
    /// `latest-only` makes this element shed on saturated subscriber
    /// queues instead of parking — one slow subscriber can no longer
    /// stall the serving pipeline. Drops are typed and counted on the
    /// topic (`drops.qos_leaky` / `drops.qos_latest`).
    pub qos: Qos,
}

impl Default for QueryServerSinkProps {
    fn default() -> Self {
        Self {
            topic: String::new(),
            transport: "inproc".to_string(),
            wait_subscribers: 0,
            qos: Qos::Blocking,
        }
    }
}

impl Props for QueryServerSinkProps {
    const FACTORY: &'static str = "tensor_query_serversink";
    const KEYS: &'static [&'static str] = &["topic", "transport", "wait-subscribers", "qos"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "topic" => self.topic = value.to_string(),
            // resolve eagerly: an unknown backend (with its nearest-name
            // suggestion) fails at construction, not at first play
            "transport" => self.transport = transport(value).map(|_| value.to_string())?,
            "wait-subscribers" => self.wait_subscribers = parse_usize(key, value)?,
            "qos" => self.qos = Qos::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorQueryServerSink::from_props(self)?))
    }
}

/// Terminal sink that publishes every input buffer on its topic. The
/// producing half of an among-device link: EOS on its sink pad ends the
/// publisher (the topic ends once every publisher finished), and a
/// saturated subscriber parks this element's task instead of a thread.
pub struct TensorQueryServerSink {
    props: QueryServerSinkProps,
    port: Option<Box<dyn PublisherPort>>,
    /// Published task waker; the transport wakes it on space/subscribe.
    wake: Arc<SharedWaker>,
}

impl TensorQueryServerSink {
    pub fn new() -> Self {
        Self::from_props(QueryServerSinkProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorQueryServerSink {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorQueryServerSink {
    type Props = QueryServerSinkProps;

    fn from_props(props: QueryServerSinkProps) -> Result<Self> {
        // typed-builder users set the field directly: validate here too,
        // so a bad backend still fails at construction
        transport(&props.transport)?;
        Ok(Self {
            props,
            port: None,
            wake: SharedWaker::new(),
        })
    }
}

impl Element for TensorQueryServerSink {
    fn type_name(&self) -> &'static str {
        "tensor_query_serversink"
    }

    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], _n: usize) -> Result<Vec<Caps>> {
        if self.props.topic.is_empty() {
            return Err(Error::Negotiation(
                "tensor_query_serversink needs topic=".into(),
            ));
        }
        // idempotent: negotiate may run again on an already-built graph
        if self.port.is_none() {
            let mut port =
                transport(&self.props.transport)?.advertise(&self.props.topic, self.props.qos)?;
            port.add_waker(&self.wake);
            port.advertise(&in_caps[0]);
            self.port = Some(port);
        }
        Ok(vec![])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            // EOS markers are accounted by the scheduler; flush detaches
            return Ok(Flow::Continue);
        };
        let Some(port) = self.port.as_mut() else {
            return Err(Error::element("tensor_query_serversink", "not negotiated"));
        };
        // publish the task waker before probing the topic, so a racing
        // subscriber drain can never free space unobserved
        self.wake.set(ctx.waker());
        let bytes = buf.size();
        if self.props.wait_subscribers > 0
            && port.subscriber_count() < self.props.wait_subscribers
        {
            if ctx.stopped() {
                port.count_dropped();
                ctx.stats().record_drop();
                return Ok(Flow::Continue);
            }
            ctx.push_back_input(pad, Item::Buffer(buf));
            return Ok(Flow::Wait);
        }
        match port.try_send(buf) {
            PortSend::Sent => {
                ctx.stats().record_out(bytes);
                Ok(Flow::Continue)
            }
            PortSend::NoSubscribers(_) => {
                // nobody listening: pub/sub semantics discard the frame
                port.count_dropped();
                ctx.stats().record_drop();
                Ok(Flow::Continue)
            }
            PortSend::Full(b) => {
                if ctx.stopped() {
                    // teardown in progress: don't wait on subscribers
                    ctx.stats().record_drop();
                    Ok(Flow::Continue)
                } else {
                    // hand the frame back and park until a subscriber
                    // drains (no pool worker held)
                    ctx.push_back_input(pad, Item::Buffer(b));
                    Ok(Flow::Wait)
                }
            }
            PortSend::Closed(_) => Ok(Flow::Eos),
        }
    }

    fn flush(&mut self, _ctx: &mut Ctx) -> Result<()> {
        // end-of-stream on every sink pad: this publisher is done — the
        // topic ends (and subscribers observe EOS) once all are
        if let Some(port) = self.port.as_mut() {
            port.finish();
        }
        Ok(())
    }

    fn on_fault(&mut self, fault: &Fault) {
        // the serving pipeline died: end the topic with the fault as its
        // close-reason so remote consumers (serversrc in another
        // pipeline, query clients) see a truncated stream, not clean EOS
        if let Some(port) = self.port.as_mut() {
            port.fail(fault);
        }
    }
}

/// Typed properties of [`TensorQueryServerSrc`].
#[derive(Debug, Clone)]
pub struct QueryServerSrcProps {
    /// Stream topic to subscribe (`topic`, required).
    pub topic: String,
    /// Delivery backend (`transport`, default `inproc`).
    pub transport: String,
    /// Caps announced downstream (`caps`; default: whatever the topic's
    /// publisher advertised, else ANY). A directly-following capsfilter
    /// also configures this, gst-launch style.
    pub caps: Caps,
    /// Bound of this subscriber's queue (`max-buffers`): a slow consumer
    /// exerts backpressure on the publisher once it fills.
    pub max_buffers: usize,
    /// Subscription QoS (`qos`, default `blocking`): with `leaky` or
    /// `latest-only`, this consumer sheds instead of backpressuring the
    /// topic's publishers when its queue fills.
    pub qos: Qos,
}

impl Default for QueryServerSrcProps {
    fn default() -> Self {
        Self {
            topic: String::new(),
            transport: "inproc".to_string(),
            caps: Caps::Any,
            max_buffers: DEFAULT_ENDPOINT_CAPACITY,
            qos: Qos::Blocking,
        }
    }
}

impl Props for QueryServerSrcProps {
    const FACTORY: &'static str = "tensor_query_serversrc";
    const KEYS: &'static [&'static str] = &["topic", "transport", "caps", "max-buffers", "qos"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "topic" => self.topic = value.to_string(),
            "transport" => self.transport = transport(value).map(|_| value.to_string())?,
            "caps" => self.caps = Caps::parse(value)?,
            "max-buffers" => self.max_buffers = parse_usize(key, value)?.max(1),
            "qos" => self.qos = Qos::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorQueryServerSrc::from_props(self)?))
    }
}

/// Source that subscribes a topic and re-emits its stream, timestamps
/// and sequence numbers untouched — the consuming half of an
/// among-device link. An idle topic parks the task ([`Flow::Wait`]);
/// topic end-of-stream becomes pipeline EOS.
///
/// [`Flow::Wait`]: crate::element::Flow::Wait
pub struct TensorQueryServerSrc {
    props: QueryServerSrcProps,
    port: Option<Box<dyn SubscriberPort>>,
    wake: Arc<SharedWaker>,
}

impl TensorQueryServerSrc {
    pub fn new() -> Self {
        Self::from_props(QueryServerSrcProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorQueryServerSrc {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorQueryServerSrc {
    type Props = QueryServerSrcProps;

    fn from_props(props: QueryServerSrcProps) -> Result<Self> {
        transport(&props.transport)?;
        Ok(Self {
            props,
            port: None,
            wake: SharedWaker::new(),
        })
    }
}

/// Announced caps: explicit configuration wins, then the topic's
/// advertisement, then ANY.
fn announced_caps(explicit: &Caps, topic: Option<Caps>) -> Caps {
    if !matches!(explicit, Caps::Any) {
        explicit.clone()
    } else {
        topic.unwrap_or(Caps::Any)
    }
}

impl Element for TensorQueryServerSrc {
    fn type_name(&self) -> &'static str {
        "tensor_query_serversrc"
    }

    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(0)
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn propose_caps(&mut self, downstream: &Caps) -> Result<()> {
        // `tensor_query_serversrc topic=x ! other/tensor,...` configures
        // the announced caps, like videotestsrc geometry
        self.props.caps = downstream.clone();
        Ok(())
    }

    fn negotiate(&mut self, _in: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        if self.props.topic.is_empty() {
            return Err(Error::Negotiation(
                "tensor_query_serversrc needs topic=".into(),
            ));
        }
        // subscribe once; the subscription exists from this point on, so
        // a publisher launched afterwards drops nothing
        if self.port.is_none() {
            let mut port = transport(&self.props.transport)?.attach(
                &self.props.topic,
                self.props.max_buffers,
                self.props.qos,
            )?;
            port.add_waker(&self.wake);
            self.port = Some(port);
        }
        let caps = announced_caps(
            &self.props.caps,
            self.port.as_ref().and_then(|p| p.topic_caps()),
        );
        Ok(vec![caps; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<Flow> {
        unreachable!("source has no sink pads")
    }

    fn generate(&mut self, ctx: &mut Ctx) -> Result<Flow> {
        let Some(port) = self.port.as_mut() else {
            return Err(Error::element("tensor_query_serversrc", "not negotiated"));
        };
        // waker first: a publish racing the empty probe still lands
        self.wake.set(ctx.waker());
        match port.try_recv() {
            PortRecv::Item(buf) => {
                ctx.push(0, buf)?;
                Ok(Flow::Continue)
            }
            PortRecv::Empty => Ok(Flow::Wait),
            PortRecv::End => {
                let reason = port.close_reason();
                // detach eagerly so a finished consumer never holds a
                // queue that would saturate the topic's publishers
                self.port = None;
                match reason {
                    // the publisher pipeline died: re-raise the fault in
                    // *this* pipeline so the truncation keeps propagating
                    Some(StreamEnd::Fault(f)) => Err(Error::Fault(f)),
                    _ => Ok(Flow::Eos),
                }
            }
        }
    }
}

/// Typed properties of [`TensorQueryClient`].
#[derive(Debug, Clone)]
pub struct QueryClientProps {
    /// Request topic of the serving pipeline (`topic`, required).
    pub topic: String,
    /// Reply topic of the serving pipeline (`reply`, required).
    pub reply: String,
    /// Delivery backend (`transport`, default `inproc`).
    pub transport: String,
    /// Caps of the replies, announced downstream (`caps`; default: the
    /// reply topic's advertisement, else ANY).
    pub caps: Caps,
    /// Reply-subscription queue bound (`max-buffers`).
    pub max_buffers: usize,
}

impl Default for QueryClientProps {
    fn default() -> Self {
        Self {
            topic: String::new(),
            reply: String::new(),
            transport: "inproc".to_string(),
            caps: Caps::Any,
            max_buffers: DEFAULT_ENDPOINT_CAPACITY,
        }
    }
}

impl Props for QueryClientProps {
    const FACTORY: &'static str = "tensor_query_client";
    const KEYS: &'static [&'static str] =
        &["topic", "reply", "transport", "caps", "max-buffers"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "topic" => self.topic = value.to_string(),
            "reply" => self.reply = value.to_string(),
            "transport" => self.transport = transport(value).map(|_| value.to_string())?,
            "caps" => self.caps = Caps::parse(value)?,
            "max-buffers" => self.max_buffers = parse_usize(key, value)?.max(1),
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorQueryClient::from_props(self)?))
    }
}

/// In-pipeline request/response filter: each input frame goes out on the
/// serving pipeline's request topic, and the service's reply is emitted
/// downstream in its place. The input frame is not consumed until its
/// reply arrived — while waiting, the task parks with the frame handed
/// back to the scheduler, so a slow (or not-yet-launched) service costs
/// no pool worker. EOS on the input finishes the request publisher,
/// which propagates end-of-stream through the service.
pub struct TensorQueryClient {
    props: QueryClientProps,
    req: Option<Box<dyn PublisherPort>>,
    rep: Option<Box<dyn SubscriberPort>>,
    wake: Arc<SharedWaker>,
    /// The current input frame's request was published; its reply is
    /// pending. Guards against re-publishing on wait/wake replays.
    awaiting: bool,
}

impl TensorQueryClient {
    pub fn new() -> Self {
        Self::from_props(QueryClientProps::default()).expect("defaults are valid")
    }
}

impl Default for TensorQueryClient {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for TensorQueryClient {
    type Props = QueryClientProps;

    fn from_props(props: QueryClientProps) -> Result<Self> {
        transport(&props.transport)?;
        Ok(Self {
            props,
            req: None,
            rep: None,
            wake: SharedWaker::new(),
            awaiting: false,
        })
    }
}

impl Element for TensorQueryClient {
    fn type_name(&self) -> &'static str {
        "tensor_query_client"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        if self.props.topic.is_empty() || self.props.reply.is_empty() {
            return Err(Error::Negotiation(
                "tensor_query_client needs topic= and reply=".into(),
            ));
        }
        if self.rep.is_none() {
            let t = transport(&self.props.transport)?;
            // subscribe the reply topic *before* attaching the request
            // publisher: no reply can be lost to ordering
            let mut rep = t.attach(&self.props.reply, self.props.max_buffers, Qos::Blocking)?;
            rep.add_waker(&self.wake);
            self.rep = Some(rep);
            let mut req = t.advertise(&self.props.topic, Qos::Blocking)?;
            req.add_waker(&self.wake);
            req.advertise(&in_caps[0]);
            self.req = Some(req);
        }
        let caps = announced_caps(
            &self.props.caps,
            self.rep.as_ref().and_then(|p| p.topic_caps()),
        );
        Ok(vec![caps; n_srcs.max(1)])
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let (Some(req), Some(rep)) = (self.req.as_mut(), self.rep.as_mut()) else {
            return Err(Error::element("tensor_query_client", "not negotiated"));
        };
        self.wake.set(ctx.waker());
        if !self.awaiting {
            // the request clone shares chunk storage; the original frame
            // stays with us until the reply arrives
            match req.try_send(buf.clone()) {
                PortSend::Sent => self.awaiting = true,
                PortSend::NoSubscribers(_) | PortSend::Full(_) => {
                    if ctx.stopped() {
                        req.count_dropped();
                        ctx.stats().record_drop();
                        return Ok(Flow::Continue);
                    }
                    // wait for the service to attach / drain
                    ctx.push_back_input(pad, Item::Buffer(buf));
                    return Ok(Flow::Wait);
                }
                PortSend::Closed(_) => return Ok(Flow::Eos),
            }
        }
        match rep.try_recv() {
            PortRecv::Item(reply) => {
                self.awaiting = false;
                ctx.push(0, reply)?;
                Ok(Flow::Continue)
            }
            PortRecv::Empty => {
                if ctx.stopped() {
                    // teardown: the reply may never come
                    self.awaiting = false;
                    ctx.stats().record_drop();
                    return Ok(Flow::Continue);
                }
                // reply pending: keep the frame and park until it lands
                ctx.push_back_input(pad, Item::Buffer(buf));
                Ok(Flow::Wait)
            }
            PortRecv::End => match rep.close_reason() {
                // the service died mid-stream: surface it as a typed
                // fault instead of silently ending this pipeline
                Some(StreamEnd::Fault(f)) => Err(Error::Fault(f)),
                _ => Ok(Flow::Eos),
            },
        }
    }

    fn flush(&mut self, _ctx: &mut Ctx) -> Result<()> {
        // input EOS: finish the request stream; the service pipeline
        // EOS-es in turn and its reply topic ends
        if let Some(req) = self.req.as_mut() {
            req.finish();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::stream::StreamRegistry;
    use crate::tensor::DType;

    #[test]
    fn props_validate_and_suggest() {
        let mut p = QueryServerSrcProps::default();
        p.set("topic", "faces").unwrap();
        p.set("max-buffers", "8").unwrap();
        let err = p.set("topik", "x").unwrap_err().to_string();
        assert!(err.contains("did you mean \"topic\"?"), "{err}");
        let mut s = QueryServerSinkProps::default();
        s.set("wait-subscribers", "2").unwrap();
        assert_eq!(s.wait_subscribers, 2);
    }

    #[test]
    fn transport_validates_at_construction_with_suggestion() {
        // launch-syntax path: a bad backend name fails in `set`, before
        // the pipeline ever plays, and suggests the nearest registered one
        let mut p = QueryServerSrcProps::default();
        p.set("transport", "inproc").unwrap();
        let err = p.set("transport", "inprc").unwrap_err().to_string();
        assert!(err.contains("no such tensor-query transport"), "{err}");
        assert!(err.contains("did you mean \"inproc\"?"), "{err}");
        // the rejected value was not stored
        assert_eq!(p.transport, "inproc");
        let mut s = QueryServerSinkProps::default();
        let err = s.set("transport", "bogus-backend").unwrap_err().to_string();
        assert!(err.contains("no such tensor-query transport"), "{err}");
        let mut c = QueryClientProps::default();
        assert!(c.set("transport", "inprc").is_err());

        // typed-builder path: fields set directly still validate in
        // `from_props`
        let err = TensorQueryServerSink::from_props(QueryServerSinkProps {
            topic: "unit/q-validate".into(),
            transport: "inprc".into(),
            ..Default::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("did you mean \"inproc\"?"), "{err}");
        assert!(TensorQueryServerSrc::from_props(QueryServerSrcProps {
            topic: "unit/q-validate".into(),
            transport: "nope".into(),
            ..Default::default()
        })
        .is_err());
        assert!(TensorQueryClient::from_props(QueryClientProps {
            topic: "unit/q-validate".into(),
            reply: "unit/q-validate-r".into(),
            transport: "nope".into(),
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn qos_property_parses_on_both_server_elements() {
        let mut s = QueryServerSinkProps::default();
        s.set("qos", "leaky").unwrap();
        assert_eq!(s.qos, Qos::Leaky);
        let mut r = QueryServerSrcProps::default();
        r.set("qos", "latest-only").unwrap();
        assert_eq!(r.qos, Qos::LatestOnly);
        let err = s.set("qos", "bogus").unwrap_err().to_string();
        assert!(err.contains("blocking | leaky | latest-only"), "{err}");
    }

    #[test]
    fn serversink_requires_topic() {
        let mut e = TensorQueryServerSink::new();
        assert!(e.negotiate(&[Caps::Any], 0).is_err());
    }

    #[test]
    fn serversrc_announces_explicit_caps() {
        let mut e = TensorQueryServerSrc::from_props(QueryServerSrcProps {
            topic: "unit/q-caps".into(),
            caps: Caps::tensor(DType::F32, [4], 30.0),
            ..Default::default()
        })
        .unwrap();
        let out = e.negotiate(&[], 1).unwrap();
        assert!(out[0].compatible(&Caps::tensor(DType::F32, [4], 30.0)));
    }

    #[test]
    fn serversrc_adopts_advertised_topic_caps() {
        let reg = StreamRegistry::global();
        let p = reg.publish("unit/q-adopt");
        p.advertise(&Caps::tensor(DType::U8, [3, 8, 8], 15.0));
        let mut e = TensorQueryServerSrc::from_props(QueryServerSrcProps {
            topic: "unit/q-adopt".into(),
            ..Default::default()
        })
        .unwrap();
        let out = e.negotiate(&[], 1).unwrap();
        assert!(out[0].compatible(&Caps::tensor(DType::U8, [3, 8, 8], 15.0)));
    }

    #[test]
    fn client_requires_both_topics() {
        let mut e = TensorQueryClient::new();
        assert!(e.negotiate(&[Caps::Any], 1).is_err());
        let mut e = TensorQueryClient::from_props(QueryClientProps {
            topic: "only-request".into(),
            ..Default::default()
        })
        .unwrap();
        assert!(e.negotiate(&[Caps::Any], 1).is_err());
    }
}
