//! Off-the-shelf media filters: `videoconvert`, `videoscale`, `videocrop`,
//! `videoflip`.
//!
//! These are the P4 components: reusing them (instead of re-implementing
//! pre-processing inside the AI framework, as MediaPipe does) is one of the
//! paper's core arguments, quantified in E4's pre-processor comparison.

use crate::element::{Ctx, Element, Flow, Item};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, ChunkPool, VideoFormat, VideoInfo};
use crate::video::{convert_into, crop_into, crop_rect, scale_bilinear_into};

use super::sources::parse_usize;

/// Pixel-format conversion. Property: `format` (target).
pub struct VideoConvert {
    target: VideoFormat,
    in_info: Option<VideoInfo>,
}

impl VideoConvert {
    pub fn new() -> Self {
        Self {
            target: VideoFormat::Rgb,
            in_info: None,
        }
    }
}

impl Default for VideoConvert {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for VideoConvert {
    fn type_name(&self) -> &'static str {
        "videoconvert"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "format" => {
                self.target = VideoFormat::parse(value)?;
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of videoconvert".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "videoconvert needs video input, got {}",
                in_caps[0]
            )));
        };
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.format = self.target;
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let out_buf = if v.format == self.target {
            buf // zero-copy passthrough: forward the input chunk untouched
        } else {
            let mut data =
                ChunkPool::global().take(self.target.frame_size(v.width, v.height));
            convert_into(
                v.format,
                self.target,
                v.width,
                v.height,
                buf.chunk().as_bytes(),
                &mut data,
            );
            let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
            out.seq = buf.seq;
            out.duration_ns = buf.duration_ns;
            out
        };
        ctx.push(0, out_buf)?;
        Ok(Flow::Continue)
    }
}

/// Bilinear scaling. Properties: `width`, `height`.
pub struct VideoScale {
    width: usize,
    height: usize,
    in_info: Option<VideoInfo>,
}

impl VideoScale {
    pub fn new() -> Self {
        Self {
            width: 0,
            height: 0,
            in_info: None,
        }
    }
}

impl Default for VideoScale {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for VideoScale {
    fn type_name(&self) -> &'static str {
        "videoscale"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of videoscale".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "videoscale needs video input, got {}",
                in_caps[0]
            )));
        };
        if v.format == VideoFormat::Nv12 {
            return Err(Error::Negotiation(
                "videoscale: convert NV12 to RGB before scaling".into(),
            ));
        }
        if self.width == 0 || self.height == 0 {
            return Err(Error::Negotiation(
                "videoscale needs width= and height=".into(),
            ));
        }
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.width = self.width;
        out.height = self.height;
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let out_buf = if v.width == self.width && v.height == self.height {
            buf
        } else {
            let ch = v.format.channels();
            let mut data = ChunkPool::global().take(self.width * self.height * ch);
            scale_bilinear_into(
                v.format,
                v.width,
                v.height,
                self.width,
                self.height,
                buf.chunk().as_bytes(),
                &mut data,
            );
            let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
            out.seq = buf.seq;
            out.duration_ns = buf.duration_ns;
            out
        };
        ctx.push(0, out_buf)?;
        Ok(Flow::Continue)
    }
}

/// Rectangle crop. Properties: `left`, `top`, `width`, `height`.
pub struct VideoCrop {
    left: usize,
    top: usize,
    width: usize,
    height: usize,
    in_info: Option<VideoInfo>,
}

impl VideoCrop {
    pub fn new() -> Self {
        Self {
            left: 0,
            top: 0,
            width: 0,
            height: 0,
            in_info: None,
        }
    }
}

impl Default for VideoCrop {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for VideoCrop {
    fn type_name(&self) -> &'static str {
        "videocrop"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "left" => self.left = parse_usize(key, value)?,
            "top" => self.top = parse_usize(key, value)?,
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            _ => {
                return Err(Error::Property {
                    key: key.into(),
                    value: value.into(),
                    reason: "unknown property of videocrop".into(),
                })
            }
        }
        Ok(())
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation("videocrop needs video input".into()));
        };
        if self.width == 0 || self.height == 0 {
            return Err(Error::Negotiation("videocrop needs width/height".into()));
        }
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.width = self.width.min(v.width - self.left.min(v.width));
        out.height = self.height.min(v.height - self.top.min(v.height));
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let ch = v.format.channels();
        let (x, y, w, h) =
            crop_rect(v.width, v.height, self.left, self.top, self.width, self.height);
        let mut data = ChunkPool::global().take(w * h * ch);
        crop_into(v.format, v.width, x, y, w, h, buf.chunk().as_bytes(), &mut data);
        let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
        out.seq = buf.seq;
        ctx.push(0, out)?;
        Ok(Flow::Continue)
    }
}

/// Horizontal/vertical flip. Property: `method` (horizontal|vertical).
pub struct VideoFlip {
    horizontal: bool,
    in_info: Option<VideoInfo>,
}

impl VideoFlip {
    pub fn new() -> Self {
        Self {
            horizontal: true,
            in_info: None,
        }
    }
}

impl Default for VideoFlip {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for VideoFlip {
    fn type_name(&self) -> &'static str {
        "videoflip"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "method" => {
                self.horizontal = value == "horizontal";
                Ok(())
            }
            _ => Err(Error::Property {
                key: key.into(),
                value: value.into(),
                reason: "unknown property of videoflip".into(),
            }),
        }
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation("videoflip needs video input".into()));
        };
        self.in_info = Some(v.clone());
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let ch = v.format.channels();
        let src = buf.chunk().as_bytes();
        let mut out = ChunkPool::global().take(src.len());
        let (w, h) = (v.width, v.height);
        if self.horizontal {
            for y in 0..h {
                for x in 0..w {
                    let s = (y * w + x) * ch;
                    let d = (y * w + (w - 1 - x)) * ch;
                    out[d..d + ch].copy_from_slice(&src[s..s + ch]);
                }
            }
        } else {
            for y in 0..h {
                let s = y * w * ch;
                let d = (h - 1 - y) * w * ch;
                out[d..d + w * ch].copy_from_slice(&src[s..s + w * ch]);
            }
        }
        let mut ob = Buffer::single(buf.pts_ns, Chunk::from_pooled(out));
        ob.seq = buf.seq;
        ctx.push(0, ob)?;
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::drive;

    #[test]
    fn convert_rgb_to_gray() {
        let mut el = VideoConvert::new();
        el.set_property("format", "GRAY8").unwrap();
        let caps = Caps::parse("video/x-raw,format=RGB,width=2,height=1,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![255, 255, 255, 0, 0, 0]));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out.len(), 1);
        let g = out[0].chunk().as_bytes_unaccounted();
        assert!(g[0] >= 254 && g[1] <= 1);
    }

    #[test]
    fn scale_halves() {
        let mut el = VideoScale::new();
        el.set_property("width", "2").unwrap();
        el.set_property("height", "2").unwrap();
        let caps = Caps::parse("video/x-raw,format=GRAY8,width=4,height=4,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec((0..16).collect()));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out[0].chunk().as_bytes_unaccounted().len(), 4);
    }

    #[test]
    fn same_format_convert_forwards_the_input_chunk() {
        // satellite: matching formats must be a true zero-copy passthrough
        let mut el = VideoConvert::new();
        el.set_property("format", "RGB").unwrap();
        let caps = Caps::parse("video/x-raw,format=RGB,width=2,height=2,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![7u8; 2 * 2 * 3]));
        let p = buf.chunk().ptr();
        let out = drive(&mut el, 0, buf);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].chunk().ptr(), p, "same-format must not copy");
    }

    #[test]
    fn flip_horizontal() {
        let mut el = VideoFlip::new();
        let caps = Caps::parse("video/x-raw,format=GRAY8,width=3,height=1,framerate=1").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![1, 2, 3]));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out[0].chunk().as_bytes_unaccounted(), &[3, 2, 1]);
    }
}
