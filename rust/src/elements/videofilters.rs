//! Off-the-shelf media filters: `videoconvert`, `videoscale`, `videocrop`,
//! `videoflip`.
//!
//! These are the P4 components: reusing them (instead of re-implementing
//! pre-processing inside the AI framework, as MediaPipe does) is one of the
//! paper's core arguments, quantified in E4's pre-processor comparison.

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{Buffer, Caps, Chunk, ChunkPool, VideoFormat, VideoInfo};
use crate::video::{convert_into, crop_into, crop_rect, scale_bilinear_into};

use super::sources::parse_usize;

/// Typed properties of [`VideoConvert`].
#[derive(Debug, Clone, Copy)]
pub struct VideoConvertProps {
    /// Target pixel format (`format`).
    pub format: VideoFormat,
}

impl Default for VideoConvertProps {
    fn default() -> Self {
        Self {
            format: VideoFormat::Rgb,
        }
    }
}

impl Props for VideoConvertProps {
    const FACTORY: &'static str = "videoconvert";
    const KEYS: &'static [&'static str] = &["format"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "format" => self.format = VideoFormat::parse(value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(VideoConvert::from_props(self)?))
    }
}

/// Pixel-format conversion.
pub struct VideoConvert {
    props: VideoConvertProps,
    in_info: Option<VideoInfo>,
}

impl VideoConvert {
    pub fn new() -> Self {
        Self::from_props(VideoConvertProps::default()).expect("defaults are valid")
    }
}

impl Default for VideoConvert {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for VideoConvert {
    type Props = VideoConvertProps;

    fn from_props(props: VideoConvertProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
        })
    }
}

impl Element for VideoConvert {
    fn type_name(&self) -> &'static str {
        "videoconvert"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "videoconvert needs video input, got {}",
                in_caps[0]
            )));
        };
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.format = self.props.format;
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let target = self.props.format;
        let out_buf = if v.format == target {
            buf // zero-copy passthrough: forward the input chunk untouched
        } else {
            let mut data = ChunkPool::global().take(target.frame_size(v.width, v.height));
            convert_into(
                v.format,
                target,
                v.width,
                v.height,
                buf.chunk().as_bytes(),
                &mut data,
            );
            let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
            out.seq = buf.seq;
            out.duration_ns = buf.duration_ns;
            out
        };
        ctx.push(0, out_buf)?;
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`VideoScale`].
#[derive(Debug, Clone, Copy, Default)]
pub struct VideoScaleProps {
    /// Target width (`width`, required).
    pub width: usize,
    /// Target height (`height`, required).
    pub height: usize,
}

impl Props for VideoScaleProps {
    const FACTORY: &'static str = "videoscale";
    const KEYS: &'static [&'static str] = &["width", "height"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(VideoScale::from_props(self)?))
    }
}

/// Bilinear scaling.
pub struct VideoScale {
    props: VideoScaleProps,
    in_info: Option<VideoInfo>,
}

impl VideoScale {
    pub fn new() -> Self {
        Self::from_props(VideoScaleProps::default()).expect("defaults are valid")
    }
}

impl Default for VideoScale {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for VideoScale {
    type Props = VideoScaleProps;

    fn from_props(props: VideoScaleProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
        })
    }
}

impl Element for VideoScale {
    fn type_name(&self) -> &'static str {
        "videoscale"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation(format!(
                "videoscale needs video input, got {}",
                in_caps[0]
            )));
        };
        if v.format == VideoFormat::Nv12 {
            return Err(Error::Negotiation(
                "videoscale: convert NV12 to RGB before scaling".into(),
            ));
        }
        if self.props.width == 0 || self.props.height == 0 {
            return Err(Error::Negotiation(
                "videoscale needs width= and height=".into(),
            ));
        }
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.width = self.props.width;
        out.height = self.props.height;
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let (tw, th) = (self.props.width, self.props.height);
        let out_buf = if v.width == tw && v.height == th {
            buf
        } else {
            let ch = v.format.channels();
            let mut data = ChunkPool::global().take(tw * th * ch);
            scale_bilinear_into(
                v.format,
                v.width,
                v.height,
                tw,
                th,
                buf.chunk().as_bytes(),
                &mut data,
            );
            let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
            out.seq = buf.seq;
            out.duration_ns = buf.duration_ns;
            out
        };
        ctx.push(0, out_buf)?;
        Ok(Flow::Continue)
    }
}

/// Typed properties of [`VideoCrop`].
#[derive(Debug, Clone, Copy, Default)]
pub struct VideoCropProps {
    pub left: usize,
    pub top: usize,
    /// Crop width (`width`, required).
    pub width: usize,
    /// Crop height (`height`, required).
    pub height: usize,
}

impl Props for VideoCropProps {
    const FACTORY: &'static str = "videocrop";
    const KEYS: &'static [&'static str] = &["left", "top", "width", "height"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "left" => self.left = parse_usize(key, value)?,
            "top" => self.top = parse_usize(key, value)?,
            "width" => self.width = parse_usize(key, value)?,
            "height" => self.height = parse_usize(key, value)?,
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(VideoCrop::from_props(self)?))
    }
}

/// Rectangle crop.
pub struct VideoCrop {
    props: VideoCropProps,
    in_info: Option<VideoInfo>,
}

impl VideoCrop {
    pub fn new() -> Self {
        Self::from_props(VideoCropProps::default()).expect("defaults are valid")
    }
}

impl Default for VideoCrop {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for VideoCrop {
    type Props = VideoCropProps;

    fn from_props(props: VideoCropProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
        })
    }
}

impl Element for VideoCrop {
    fn type_name(&self) -> &'static str {
        "videocrop"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation("videocrop needs video input".into()));
        };
        if self.props.width == 0 || self.props.height == 0 {
            return Err(Error::Negotiation("videocrop needs width/height".into()));
        }
        self.in_info = Some(v.clone());
        let mut out = v.clone();
        out.width = self.props.width.min(v.width - self.props.left.min(v.width));
        out.height = self
            .props
            .height
            .min(v.height - self.props.top.min(v.height));
        Ok(vec![Caps::Video(out); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let ch = v.format.channels();
        let (x, y, w, h) = crop_rect(
            v.width,
            v.height,
            self.props.left,
            self.props.top,
            self.props.width,
            self.props.height,
        );
        let mut data = ChunkPool::global().take(w * h * ch);
        crop_into(v.format, v.width, x, y, w, h, buf.chunk().as_bytes(), &mut data);
        let mut out = Buffer::single(buf.pts_ns, Chunk::from_pooled(data));
        out.seq = buf.seq;
        ctx.push(0, out)?;
        Ok(Flow::Continue)
    }
}

/// Flip direction of [`VideoFlip`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlipMethod {
    Horizontal,
    Vertical,
}

/// Typed properties of [`VideoFlip`].
#[derive(Debug, Clone, Copy)]
pub struct VideoFlipProps {
    /// Flip axis (`method=horizontal|vertical`).
    pub method: FlipMethod,
}

impl Default for VideoFlipProps {
    fn default() -> Self {
        Self {
            method: FlipMethod::Horizontal,
        }
    }
}

impl Props for VideoFlipProps {
    const FACTORY: &'static str = "videoflip";
    const KEYS: &'static [&'static str] = &["method"];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            // launch-string compatibility: anything except "horizontal"
            // selects the vertical flip
            "method" => {
                self.method = if value == "horizontal" {
                    FlipMethod::Horizontal
                } else {
                    FlipMethod::Vertical
                }
            }
            _ => return Err(unknown_property(Self::FACTORY, Self::KEYS, key, value)),
        }
        Ok(())
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(VideoFlip::from_props(self)?))
    }
}

/// Horizontal/vertical flip.
pub struct VideoFlip {
    props: VideoFlipProps,
    in_info: Option<VideoInfo>,
}

impl VideoFlip {
    pub fn new() -> Self {
        Self::from_props(VideoFlipProps::default()).expect("defaults are valid")
    }
}

impl Default for VideoFlip {
    fn default() -> Self {
        Self::new()
    }
}

impl FromProps for VideoFlip {
    type Props = VideoFlipProps;

    fn from_props(props: VideoFlipProps) -> Result<Self> {
        Ok(Self {
            props,
            in_info: None,
        })
    }
}

impl Element for VideoFlip {
    fn type_name(&self) -> &'static str {
        "videoflip"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        self.props.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let Caps::Video(v) = &in_caps[0] else {
            return Err(Error::Negotiation("videoflip needs video input".into()));
        };
        self.in_info = Some(v.clone());
        Ok(vec![in_caps[0].clone(); n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(buf) = item else {
            return Ok(Flow::Continue);
        };
        let v = self.in_info.as_ref().unwrap();
        let ch = v.format.channels();
        let src = buf.chunk().as_bytes();
        let mut out = ChunkPool::global().take(src.len());
        let (w, h) = (v.width, v.height);
        if self.props.method == FlipMethod::Horizontal {
            for y in 0..h {
                for x in 0..w {
                    let s = (y * w + x) * ch;
                    let d = (y * w + (w - 1 - x)) * ch;
                    out[d..d + ch].copy_from_slice(&src[s..s + ch]);
                }
            }
        } else {
            for y in 0..h {
                let s = y * w * ch;
                let d = (h - 1 - y) * w * ch;
                out[d..d + w * ch].copy_from_slice(&src[s..s + w * ch]);
            }
        }
        let mut ob = Buffer::single(buf.pts_ns, Chunk::from_pooled(out));
        ob.seq = buf.seq;
        ctx.push(0, ob)?;
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::testutil::drive;

    #[test]
    fn convert_rgb_to_gray() {
        let mut el = VideoConvert::new();
        el.set_property("format", "GRAY8").unwrap();
        let caps = Caps::parse("video/x-raw,format=RGB,width=2,height=1,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![255, 255, 255, 0, 0, 0]));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out.len(), 1);
        let g = out[0].chunk().as_bytes_unaccounted();
        assert!(g[0] >= 254 && g[1] <= 1);
    }

    #[test]
    fn scale_halves() {
        let mut el = VideoScale::from_props(VideoScaleProps {
            width: 2,
            height: 2,
        })
        .unwrap();
        let caps = Caps::parse("video/x-raw,format=GRAY8,width=4,height=4,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec((0..16).collect()));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out[0].chunk().as_bytes_unaccounted().len(), 4);
    }

    #[test]
    fn same_format_convert_forwards_the_input_chunk() {
        // satellite: matching formats must be a true zero-copy passthrough
        let mut el = VideoConvert::new();
        el.set_property("format", "RGB").unwrap();
        let caps = Caps::parse("video/x-raw,format=RGB,width=2,height=2,framerate=30").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![7u8; 2 * 2 * 3]));
        let p = buf.chunk().ptr();
        let out = drive(&mut el, 0, buf);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].chunk().ptr(), p, "same-format must not copy");
    }

    #[test]
    fn flip_horizontal() {
        let mut el = VideoFlip::new();
        let caps = Caps::parse("video/x-raw,format=GRAY8,width=3,height=1,framerate=1").unwrap();
        el.negotiate(&[caps], 1).unwrap();
        let buf = Buffer::single(0, Chunk::from_vec(vec![1, 2, 3]));
        let out = drive(&mut el, 0, buf);
        assert_eq!(out[0].chunk().as_bytes_unaccounted(), &[3, 2, 1]);
    }

    #[test]
    fn typed_props_reject_unknown_keys_with_suggestion() {
        let mut p = VideoScaleProps::default();
        let err = p.set("widht", "4").unwrap_err().to_string();
        assert!(err.contains("did you mean \"width\"?"), "{err}");
    }
}
