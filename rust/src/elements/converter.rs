//! `tensor_converter`: media streams → `other/tensor` streams (§III).
//!
//! Video frames become uint8 tensors of dimension `C:W:H` (NNStreamer's
//! dimension order for video); audio becomes `S:C` int16; text/flatbuf
//! become opaque byte tensors. NV12 input is converted to RGB first, like
//! NNStreamer's converter requires RGB/GRAY8 (we fold the conversion in
//! for convenience, as real pipelines put `videoconvert` before it).

use crate::element::props::unknown_property;
use crate::element::{Ctx, Element, Flow, FromProps, Item, Props};
use crate::error::{Error, Result};
use crate::tensor::{
    Buffer, Caps, Chunk, ChunkPool, DType, Dims, TensorInfo, VideoFormat, VideoInfo,
};
use crate::video::convert::convert_into;

/// Typed properties of [`TensorConverter`] (none — conversion is fully
/// driven by the negotiated input caps).
#[derive(Debug, Clone, Copy, Default)]
pub struct TensorConverterProps;

impl Props for TensorConverterProps {
    const FACTORY: &'static str = "tensor_converter";
    const KEYS: &'static [&'static str] = &[];

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        Err(unknown_property(Self::FACTORY, Self::KEYS, key, value))
    }

    fn into_element(self) -> Result<Box<dyn Element>> {
        Ok(Box::new(TensorConverter::from_props(self)?))
    }
}

pub struct TensorConverter {
    in_video: Option<VideoInfo>,
}

impl TensorConverter {
    pub fn new() -> Self {
        Self { in_video: None }
    }
}

impl FromProps for TensorConverter {
    type Props = TensorConverterProps;

    fn from_props(_props: TensorConverterProps) -> Result<Self> {
        Ok(Self::new())
    }
}

impl Default for TensorConverter {
    fn default() -> Self {
        Self::new()
    }
}

impl Element for TensorConverter {
    fn type_name(&self) -> &'static str {
        "tensor_converter"
    }

    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        TensorConverterProps.set(key, value)
    }

    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>> {
        let out = match &in_caps[0] {
            Caps::Video(v) => {
                self.in_video = Some(v.clone());
                let ch = v.format.channels();
                Caps::Tensor {
                    info: TensorInfo::new(DType::U8, Dims::new(&[ch, v.width, v.height])),
                    fps_millis: v.fps_millis,
                }
            }
            Caps::Audio(a) => Caps::Tensor {
                info: TensorInfo::new(
                    DType::I16,
                    Dims::new(&[a.samples_per_buffer, a.channels]),
                ),
                fps_millis: 0,
            },
            Caps::Text | Caps::FlatBuf => Caps::Tensor {
                info: TensorInfo::new(DType::U8, Dims::new(&[1])),
                fps_millis: 0,
            },
            // tensors pass through unchanged (converter is idempotent)
            t @ (Caps::Tensor { .. } | Caps::Tensors { .. }) => t.clone(),
            Caps::Any => {
                return Err(Error::Negotiation(
                    "tensor_converter needs fixed upstream caps".into(),
                ))
            }
        };
        Ok(vec![out; n_srcs.max(1)])
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
        let Item::Buffer(mut buf) = item else {
            return Ok(Flow::Continue);
        };
        if let Some(v) = &self.in_video {
            let chunk = if v.format == VideoFormat::Nv12 {
                let mut rgb = ChunkPool::global()
                    .take(VideoFormat::Rgb.frame_size(v.width, v.height));
                convert_into(
                    VideoFormat::Nv12,
                    VideoFormat::Rgb,
                    v.width,
                    v.height,
                    buf.chunk().as_bytes(),
                    &mut rgb,
                );
                Chunk::from_pooled(rgb)
            } else {
                // zero-copy: u8 video payload is already the tensor payload
                buf.chunks.remove(0)
            };
            let mut out = Buffer::single(buf.pts_ns, chunk);
            out.seq = buf.seq;
            out.duration_ns = buf.duration_ns;
            ctx.push(0, out)?;
        } else {
            // audio/text/tensor: payload is forwarded as-is
            ctx.push(0, buf)?;
        }
        Ok(Flow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn video_to_tensor_caps() {
        let mut c = TensorConverter::new();
        let caps = Caps::parse("video/x-raw,format=RGB,width=64,height=48,framerate=30").unwrap();
        let out = c.negotiate(&[caps], 1).unwrap();
        match &out[0] {
            Caps::Tensor { info, fps_millis } => {
                assert_eq!(info.dims.as_slice(), &[3, 64, 48]);
                assert_eq!(info.dtype, DType::U8);
                assert_eq!(*fps_millis, 30000);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tensor_passthrough() {
        let mut c = TensorConverter::new();
        let caps = Caps::tensor(DType::F32, [4, 4], 10.0);
        let out = c.negotiate(&[caps.clone()], 1).unwrap();
        assert_eq!(out[0], caps);
    }
}
