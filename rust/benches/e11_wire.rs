//! **E11 — TCP wire transport vs in-process topic link.**
//!
//! The E8 chain (camera → normalize | I3 inference) split at the
//! normalized-tensor link into two pipelines joined by a tensor-query
//! topic, run two ways on the same hub:
//!
//! * **inproc** — the PR 5 stream-endpoint link (shared memory);
//! * **tcp** — the same element graph with `transport=tcp`: frames
//!   cross a loopback socket through the framed wire codec with
//!   credit-based flow control, discovery via a [`NetRegistry`].
//!
//! Asserts sink output **bit-identical** across the wire and prints
//! throughput plus the subscriber-queue latency percentiles of both
//! links — the cost of leaving the process.
//!
//! ```bash
//! cargo bench --bench e11_wire [-- --full] [-- --record]
//! ```
//!
//! `--record` writes `../artifacts/BENCH_e11_wire.json`
//! (the `make bench-smoke` target).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::net::{register_tcp, NetRegistry, TcpConfig};
use nnstreamer::pipeline::{Pipeline, PipelineHub};

const WORKERS: usize = 4;

fn head(frames: u64) -> String {
    format!(
        "videotestsrc name=src pattern=ball width=320 height=240 framerate=2400 \
         num-buffers={frames} is-live=false ! tee name=t t. ! queue ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255"
    )
}

const TAIL: &str = "tensor_filter framework=xla model=i3_opt accelerator=cpu ! \
                    tensor_decoder mode=image_labeling ! tensor_sink name=out";

const LINK_CAPS: &str = "other/tensor,dimension=3:64:64,type=float32,framerate=2400";

fn sink_bytes(p: &mut Pipeline) -> Vec<Vec<u8>> {
    let el = p.finished_element("out").expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| b.chunk().as_bytes_unaccounted().to_vec())
        .collect()
}

fn run_direct(frames: u64) -> Vec<Vec<u8>> {
    let hub = PipelineHub::with_workers(WORKERS);
    let p = Pipeline::parse(&format!("{} ! {}", head(frames), TAIL)).unwrap();
    hub.launch("direct", p).unwrap();
    let mut joined = hub.join_all();
    let j = joined.pop().unwrap();
    j.report.expect("direct run succeeded");
    let mut pipeline = j.pipeline;
    sink_bytes(&mut pipeline)
}

/// One split run over `transport`; returns the sink payloads, the wall
/// time, and the subscriber-queue latency percentiles (p50, p99) in µs.
fn run_split(frames: u64, topic: &str, transport: &str) -> (Vec<Vec<u8>>, f64, (f64, f64)) {
    let hub = PipelineHub::with_workers(WORKERS);
    let back = Pipeline::parse(&format!(
        "tensor_query_serversrc topic={topic} transport={transport} max-buffers=8 ! \
         {LINK_CAPS} ! {TAIL}"
    ))
    .unwrap();
    // wait-subscribers=1: the TCP subscriber connects asynchronously,
    // so the publisher parks instead of dropping pre-connection frames
    let front = Pipeline::parse(&format!(
        "{} ! tensor_query_serversink topic={topic} transport={transport} wait-subscribers=1",
        head(frames)
    ))
    .unwrap();
    let t0 = Instant::now();
    hub.launch("back", back).unwrap();
    hub.launch("front", front).unwrap();
    let mut out = Vec::new();
    let mut lat = (0.0, 0.0);
    // the subscriber-side queue entry: plain topic name for inproc,
    // `tcp-sub:` prefixed for the wire transport
    let sub_entry = if transport == "tcp" {
        format!("tcp-sub:{topic}")
    } else {
        topic.to_string()
    };
    for j in hub.join_all() {
        let report = j.report.expect("split run succeeded");
        let mut pipeline = j.pipeline;
        if j.name == "back" {
            out = sink_bytes(&mut pipeline);
            let t = report
                .topic(&sub_entry)
                .unwrap_or_else(|| panic!("{sub_entry} missing from report"));
            assert_eq!(
                t.pushed,
                t.delivered + t.dropped + t.in_flight,
                "conservation violated on {sub_entry}"
            );
            lat = (
                t.latency.p50.as_secs_f64() * 1e6,
                t.latency.p99.as_secs_f64() * 1e6,
            );
        }
    }
    (out, t0.elapsed().as_secs_f64(), lat)
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(64, 600);
    let repeats = args.repeats.max(3);
    let record = std::env::args().any(|a| a == "--record");

    harness::warm_models(&["i3_opt"]);

    // one registry + transport instance for every round
    let registry = NetRegistry::serve("127.0.0.1:0").expect("discovery registry");
    register_tcp(TcpConfig::new(registry.addr().to_string()));

    let reference = run_direct(frames);
    assert_eq!(reference.len(), frames as usize, "direct run kept all frames");

    let (mut inproc_s, mut tcp_s) = (Vec::new(), Vec::new());
    let (mut inproc_lat, mut tcp_lat) = ((0.0, 0.0), (0.0, 0.0));
    for round in 0..repeats {
        let (q, qt, ql) = run_split(frames, &format!("e11/inproc-{round}"), "inproc");
        assert_eq!(q, reference, "inproc topic link must be bit-identical");
        inproc_s.push(qt);
        inproc_lat = ql;
        let (w, wt, wl) = run_split(frames, &format!("e11/wire-{round}"), "tcp");
        assert_eq!(
            w, reference,
            "sink output must be bit-identical across the TCP wire"
        );
        tcp_s.push(wt);
        tcp_lat = wl;
    }

    let (im, is) = harness::mean_std(&inproc_s);
    let (tm, ts) = harness::mean_std(&tcp_s);
    let (ifps, tfps) = (frames as f64 / im, frames as f64 / tm);
    println!("E11: {frames} frames x {repeats} runs on {WORKERS} workers");
    println!(
        "  inproc link   {} s   ({ifps:.1} frames/s)   queue p50/p99 {:.0}/{:.0} us",
        harness::pm(im, is, 3),
        inproc_lat.0,
        inproc_lat.1
    );
    println!(
        "  tcp link      {} s   ({tfps:.1} frames/s)   queue p50/p99 {:.0}/{:.0} us",
        harness::pm(tm, ts, 3),
        tcp_lat.0,
        tcp_lat.1
    );
    println!(
        "  wire overhead: {:+.1}% wall vs the in-process link",
        (tm / im - 1.0) * 100.0
    );

    if record {
        let json = format!(
            "{{\n  \"bench\": \"e11_wire\",\n  \"pipeline\": \"E8 chain split at the tensor link (i3_opt, cpu)\",\n  \"frames_per_run\": {frames},\n  \"fps_inproc\": {ifps:.2},\n  \"fps_tcp\": {tfps:.2},\n  \"wire_overhead\": {:.4},\n  \"queue_p50_us_inproc\": {:.1},\n  \"queue_p99_us_inproc\": {:.1},\n  \"queue_p50_us_tcp\": {:.1},\n  \"queue_p99_us_tcp\": {:.1},\n  \"bit_identical_output\": true\n}}\n",
            tm / im - 1.0,
            inproc_lat.0,
            inproc_lat.1,
            tcp_lat.0,
            tcp_lat.1,
        );
        // same ./artifacts vs ../artifacts resolution as ModelRegistry
        let path = if std::path::Path::new("../artifacts/manifest.txt").exists()
            && !std::path::Path::new("artifacts/manifest.txt").exists()
        {
            "../artifacts/BENCH_e11_wire.json"
        } else {
            "artifacts/BENCH_e11_wire.json"
        };
        std::fs::write(path, json).expect("write snapshot");
        println!("recorded {path}");
    }

    println!("e11_wire: OK (bit-identical sink output across the wire)");
}
