//! Regenerates **Table II** (E3): MTCNN cascade performance, Control
//! (serial, ROS-team style) vs NNStreamer, across the three device
//! classes (A mid-end embedded / B high-end embedded / C PC).
//!
//! ```bash
//! cargo bench --bench e3_table2 [-- --full]
//! ```
//!
//! Expected shape: NNS wins throughput on every class (biggest win on the
//! embedded classes where functional parallelism has the most headroom);
//! P-Net latency improves (parallel pyramid branches); R/O-Net stage
//! latencies may regress slightly (the paper reports −6.6%/−18%: extra
//! mux/patch hops), overall latency improves.

#[path = "harness.rs"]
mod harness;

use nnstreamer::apps::e3_mtcnn::{run_control, run_nns, MtcnnConfig, MtcnnReport};
use nnstreamer::devices::DeviceClass;
use nnstreamer::metrics::report::{f, Table};

fn geo_mean_ratio(pairs: &[(f64, f64)]) -> f64 {
    let mut p = 1.0;
    for (c, n) in pairs {
        p *= n / c;
    }
    p.powf(1.0 / pairs.len() as f64)
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(8, 90);
    harness::warm_models(&[
        "pnet_s0_opt",
        "pnet_s1_opt",
        "pnet_s2_opt",
        "pnet_s3_opt",
        "pnet_s4_opt",
        "rnet_opt",
        "onet_opt",
    ]);

    let classes = [
        DeviceClass::MidEmbedded,
        DeviceClass::HighEmbedded,
        DeviceClass::Pc,
    ];
    println!("E3 / Table II — MTCNN, {frames} Full-HD frames per run");

    let mut results: Vec<(DeviceClass, MtcnnReport, MtcnnReport)> = Vec::new();
    for class in classes {
        let cfg = MtcnnConfig {
            class,
            num_frames: frames,
            fps: 10_000.0, // batch mode: throughput ceiling
            live: false,
            ..Default::default()
        };
        eprintln!("  running Control on {}...", class.name());
        let ctl = run_control(&cfg).expect("control");
        eprintln!("  running NNStreamer on {}...", class.name());
        let nns = run_nns(&cfg).expect("nns");
        results.push((class, ctl, nns));
    }

    let mut t = Table::new(
        "Table II: MTCNN performance (Control vs NNStreamer)",
        &[
            "Row",
            "A Ctrl",
            "A NNS",
            "B Ctrl",
            "B NNS",
            "C Ctrl",
            "C NNS",
            "Improved(geo)",
            "Paper",
        ],
    );

    type Get = fn(&MtcnnReport) -> f64;
    let rows: [(&str, Get, &str); 4] = [
        (
            "1. Throughput (fps)",
            |r: &MtcnnReport| r.throughput_fps,
            "+82.2%",
        ),
        (
            "3. P-Net latency (ms)",
            |r: &MtcnnReport| r.pnet_latency_ms,
            "+40.1%",
        ),
        (
            "4. R-Net latency (ms)",
            |r: &MtcnnReport| r.rnet_latency_ms,
            "-6.6%",
        ),
        (
            "5. O-Net latency (ms)",
            |r: &MtcnnReport| r.onet_latency_ms,
            "-18.1%",
        ),
    ];

    for (name, get, paper) in rows {
        let mut cells = vec![name.to_string()];
        let mut pairs = Vec::new();
        for (_, ctl, nns) in &results {
            cells.push(f(get(ctl), 1));
            cells.push(f(get(nns), 1));
            pairs.push((get(ctl), get(nns)));
        }
        // throughput improves when NNS/Ctrl > 1; latencies when < 1
        let ratio = geo_mean_ratio(&pairs);
        let improved = if name.contains("Throughput") {
            (ratio - 1.0) * 100.0
        } else {
            (1.0 / ratio - 1.0) * 100.0
        };
        cells.push(format!("{improved:+.1}%"));
        cells.push(paper.to_string());
        t.row(&cells);
    }
    t.print();

    // Row 2 (overall latency): Control measures it directly; for NNS we
    // report the sum of stage latencies (single-frame-in-flight analog,
    // the paper's 1 fps methodology).
    println!("\nRow 2 (overall latency, ms; single-frame-in-flight):");
    for (class, ctl, nns) in &results {
        let nns_overall = nns.pnet_latency_ms + nns.rnet_latency_ms + nns.onet_latency_ms;
        println!(
            "  {}: Control {:.1} vs NNS {:.1} ({:+.1}%)",
            class.name(),
            ctl.overall_latency_ms,
            nns_overall,
            (1.0 - nns_overall / ctl.overall_latency_ms) * 100.0
        );
    }
    println!("  paper: +16.8% improvement (981.8->811.0, 704.5->539.4, 94.3->85.9)");
}
