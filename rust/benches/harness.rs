//! Shared bench harness (criterion is not in the offline vendor set; each
//! bench is a plain binary that prints its paper table).
#![allow(dead_code)]

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// `value ± std` cell formatting (Table III style).
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{:.*} ±{:.*}", decimals, mean, decimals, std)
}

/// Bench CLI: `--full` restores paper-scale frame counts; `--frames N`
/// overrides directly.
pub struct BenchArgs {
    pub full: bool,
    pub frames: Option<u64>,
    pub repeats: usize,
}

impl BenchArgs {
    pub fn parse() -> Self {
        let mut out = BenchArgs {
            full: false,
            frames: None,
            repeats: 1,
        };
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--full" => out.full = true,
                "--frames" => out.frames = args.next().and_then(|v| v.parse().ok()),
                "--repeats" => {
                    out.repeats = args.next().and_then(|v| v.parse().ok()).unwrap_or(1)
                }
                // `cargo bench` passes --bench; tolerate unknown flags so
                // the binaries also run under the test harness
                _ => {}
            }
        }
        out
    }

    pub fn frames_or(&self, quick: u64, full: u64) -> u64 {
        self.frames.unwrap_or(if self.full { full } else { quick })
    }
}

/// Thread count of this process (`/proc/self/status`), for bounded-
/// thread assertions. Returns None off Linux (assertion skipped).
pub fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Warm the model registry so per-case RSS deltas reflect steady state,
/// not first-compile costs.
pub fn warm_models(names: &[&str]) {
    let reg = nnstreamer::runtime::ModelRegistry::global().expect(
        "artifacts/ missing — run `make artifacts` first",
    );
    for n in names {
        reg.load(n).expect(n);
    }
}
