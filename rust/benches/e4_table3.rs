//! Regenerates **Table III** (E4): NNStreamer vs the MediaPipe-like
//! framework on SSDLite object detection, plus the pre-processor-only
//! comparison (the 25% / 40% numbers).
//!
//! ```bash
//! cargo bench --bench e4_table3 [-- --full --repeats 3]
//! ```
//!
//! Expected shape: (a) opt-NNFW ≫ (b) ref-NNFW (the paper's 3.5x from
//! NNFW-version freedom); (b) slightly better than (c) MediaPipe-like;
//! (d) hybrid close to (c); MediaPipe-like moves more bytes (row 4).

#[path = "harness.rs"]
mod harness;

use harness::pm;
use nnstreamer::apps::e4::{preprocessor_comparison, run_case, E4Case, E4Config};
use nnstreamer::metrics::report::Table;

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(150, 1818);
    let repeats = args.repeats.max(1);
    harness::warm_models(&["ssd_opt", "ssd_ref"]);

    let cfg = E4Config {
        num_frames: frames,
        ..Default::default()
    };
    println!("E4 / Table III — {frames} frames per case, {repeats} repeat(s)");

    let mut t = Table::new(
        "Table III: object detection, NNStreamer vs MediaPipe-like",
        &[
            "Row",
            "(a) NNS-opt",
            "(b) NNS-ref",
            "(c) MediaPipe",
            "(d) Hybrid",
            "Paper shape",
        ],
    );

    // collect per-case row samples
    let mut cpu = vec![vec![]; 4];
    let mut fps = vec![vec![]; 4];
    let mut lat = vec![vec![]; 4];
    let mut acc = vec![vec![]; 4];
    let mut mem = vec![vec![]; 4];
    for rep in 0..repeats {
        for (i, case) in E4Case::all().into_iter().enumerate() {
            let row = run_case(&cfg, case).expect(case.label());
            eprintln!("  rep {rep}: {} done ({:.1} fps)", row.label, row.throughput_fps);
            cpu[i].push(row.cpu_percent);
            fps[i].push(row.throughput_fps);
            lat[i].push(row.latency_ms);
            acc[i].push(row.mem_access_m);
            mem[i].push(row.mem_mib);
        }
    }

    let cell = |xs: &Vec<f64>, d: usize| {
        let (m, s) = harness::mean_std(xs);
        pm(m, s, d)
    };
    t.row(&[
        "1. CPU (%)".into(),
        cell(&cpu[0], 1),
        cell(&cpu[1], 1),
        cell(&cpu[2], 1),
        cell(&cpu[3], 1),
        "352.8 / 168.7 / 168.2 / 168.0".into(),
    ]);
    t.row(&[
        "2. Throughput (fps)".into(),
        cell(&fps[0], 1),
        cell(&fps[1], 1),
        cell(&fps[2], 1),
        cell(&fps[3], 1),
        "46.9 / 13.8 / 13.3 / 12.8".into(),
    ]);
    t.row(&[
        "3. Latency (ms)".into(),
        cell(&lat[0], 1),
        cell(&lat[1], 1),
        cell(&lat[2], 1),
        cell(&lat[3], 1),
        "20.8 / 72.7 / 74.5 / 76.3".into(),
    ]);
    t.row(&[
        "4. Mem access (M bytes)".into(),
        cell(&acc[0], 0),
        cell(&acc[1], 0),
        cell(&acc[2], 0),
        cell(&acc[3], 0),
        "21.9 / 21.8 / 23.5 / 25.3 (G accesses)".into(),
    ]);
    t.row(&[
        "5. Mem size (MiB)".into(),
        cell(&mem[0], 1),
        cell(&mem[1], 1),
        cell(&mem[2], 1),
        cell(&mem[3], 1),
        "199.5 / 194.9 / 185.1 / 300.4".into(),
    ]);
    t.print();

    let (fa, _) = harness::mean_std(&fps[0]);
    let (fb, _) = harness::mean_std(&fps[1]);
    let (fc, _) = harness::mean_std(&fps[2]);
    println!(
        "\nNNFW-version freedom: opt/ref throughput = {:.2}x (paper: 3.54x)",
        fa / fb
    );
    println!(
        "framework overhead: NNS-ref vs MediaPipe-like = {:+.1}% (paper: +3.8%)",
        (fb / fc - 1.0) * 100.0
    );

    // pre-processor comparison (paper: MP 25% slower, 40% more CPU overhead)
    let pf = args.frames_or(200, 1818);
    let ((nns_cpu, nns_real), (mp_cpu, mp_real)) =
        preprocessor_comparison(&cfg, pf).expect("preprocessor comparison");
    println!("\npre-processors only ({pf} frames):");
    println!("  NNStreamer     : cpu {nns_cpu:.2}s real {nns_real:.2}s");
    println!("  MediaPipe-like : cpu {mp_cpu:.2}s real {mp_real:.2}s");
    println!(
        "  MP is {:+.0}% slower with {:+.0}% more CPU overhead (paper: +25% / +40%)",
        (mp_real / nns_real - 1.0) * 100.0,
        (mp_cpu / nns_cpu - 1.0) * 100.0
    );
}
