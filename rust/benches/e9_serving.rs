//! **E9 — multi-tenant serving: QoS isolation under flood.**
//!
//! One 4-worker [`PipelineHub`] serves latency-sensitive *victim*
//! pipelines (live sources publishing through `qos=blocking` topics,
//! [`Priority::High`]) while a hostile tenant floods it: a non-live
//! source publishing as fast as the pool allows into a tiny **leaky**
//! subscriber that is never drained ([`Priority::Low`]), plus a storm of
//! short-lived SingleShot tenants admitted through hub invoke tickets.
//!
//! Asserts that
//! * the flooded leaky tenant's drops are charged to its own typed
//!   counters (`drops.qos_leaky`) and never gate the victims,
//! * victim p99 end-to-end latency moves by **< 20%** (plus a small
//!   absolute slack absorbing µs-scale bucket jitter) between the
//!   unloaded and flooded phases,
//! * total threads stay **O(workers)**, never O(tenants),
//! * every pipeline and topic report carries latency percentiles.
//!
//! ```bash
//! cargo bench --bench e9_serving             # quick
//! cargo bench --bench e9_serving -- --full   # longer phases, more tenants
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use nnstreamer::pipeline::{Pipeline, PipelineHub, Priority, Qos, TenantQuota};
use nnstreamer::runtime::SingleShot;

const WORKERS: usize = 4;
const VICTIMS: usize = 2;
const SHOT_THREADS: usize = 4;

/// Latency-sensitive serving pipeline: live camera at 60 fps publishing
/// tensors through a blocking topic (every frame must arrive).
fn victim_desc(tag: &str, i: usize, frames: u64) -> String {
    format!(
        "videotestsrc pattern=gradient num-buffers={frames} is-live=true ! \
         video/x-raw,format=RGB,width=32,height=32,framerate=60 ! \
         tensor_converter ! tensor_query_serversink topic=e9/{tag}/v{i} qos=blocking"
    )
}

/// Hostile tenant: non-live source producing as fast as the pool allows
/// into a leaky topic (its subscriber is tiny and never drained).
fn flood_desc(tag: &str) -> String {
    format!(
        "videotestsrc pattern=ball is-live=false ! \
         video/x-raw,format=RGB,width=64,height=64,framerate=2400 ! \
         tensor_converter ! tensor_query_serversink topic=e9/{tag}/flood qos=leaky"
    )
}

struct PhaseOut {
    victim_p99: Vec<Duration>,
    victim_p50: Vec<Duration>,
    flood_leaky_drops: u64,
    shots_done: u64,
    shots_denied: u64,
}

fn run_phase(tag: &str, frames: u64, flood: bool, shots: bool) -> PhaseOut {
    let start_threads = harness::process_threads();
    let hub = Arc::new(PipelineHub::with_workers(WORKERS));

    // victim consumers drain promptly (the app side of the service)
    let mut drains = Vec::new();
    for i in 0..VICTIMS {
        let sub = hub.subscribe_with_capacity(&format!("e9/{tag}/v{i}"), 32);
        drains.push(std::thread::spawn(move || {
            let mut n = 0u64;
            while sub.recv().is_ok() {
                n += 1;
            }
            n
        }));
    }
    for i in 0..VICTIMS {
        let p = Pipeline::parse(&victim_desc(tag, i, frames)).unwrap();
        hub.launch_as_with_priority(
            format!("victim-{i}"),
            format!("v{i}"),
            p,
            Priority::High,
        )
        .unwrap();
    }

    // the flood tenant: budgeted tiny leaky subscription, never drained
    let flood_topic = format!("e9/{tag}/flood");
    let _flood_sub = if flood {
        hub.set_quota(
            "flood",
            TenantQuota {
                max_topic_buffers: 4,
                ..Default::default()
            },
        );
        let sub = hub
            .subscribe_as("flood", &flood_topic, 4, Qos::Leaky)
            .expect("within budget");
        let p = Pipeline::parse(&flood_desc(tag)).unwrap();
        hub.launch_as_with_priority("flood", "flooder", p, Priority::Low)
            .unwrap();
        Some(sub)
    } else {
        None
    };

    // short-lived SingleShot tenants, admitted through invoke tickets
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let denied = Arc::new(AtomicU64::new(0));
    let mut shooters = Vec::new();
    if shots {
        hub.set_quota(
            "shots",
            TenantQuota {
                max_queued_invokes: 64,
                ..Default::default()
            },
        );
        for t in 0..SHOT_THREADS {
            let (hub, stop, done, denied) =
                (hub.clone(), stop.clone(), done.clone(), denied.clone());
            shooters.push(std::thread::spawn(move || {
                let input: Vec<f32> =
                    (0..128 * 3).map(|i| ((i + t) % 23) as f32 / 23.0).collect();
                while !stop.load(Ordering::Relaxed) {
                    match hub.try_admit_invoke("shots") {
                        Ok(_ticket) => {
                            let s = SingleShot::open("ars_a_opt").unwrap();
                            s.invoke(&[&input]).unwrap();
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            denied.fetch_add(1, Ordering::Relaxed);
                            std::thread::yield_now();
                        }
                    }
                }
            }));
        }
    }

    // mid-phase bounded-thread check: hub workers plus our own app
    // threads (drains + shooters), never a thread per tenant or per
    // pipeline element
    if let (Some(start), Some(during)) = (start_threads, harness::process_threads()) {
        let added = during.saturating_sub(start);
        assert!(
            added <= WORKERS + VICTIMS + SHOT_THREADS + 4,
            "threads must stay O(workers) mid-phase, got +{added}"
        );
    }

    // victims end on their own frame budget; their drains follow
    let mut delivered = 0u64;
    for d in drains {
        delivered += d.join().unwrap();
    }
    assert_eq!(
        delivered,
        frames * VICTIMS as u64,
        "blocking qos delivered every victim frame"
    );
    stop.store(true, Ordering::Relaxed);
    for s in shooters {
        s.join().unwrap();
    }
    // the flooder never ends on its own: stop under full load and join
    hub.request_stop_all();

    let mut out = PhaseOut {
        victim_p99: vec![Duration::ZERO; VICTIMS],
        victim_p50: vec![Duration::ZERO; VICTIMS],
        flood_leaky_drops: 0,
        shots_done: done.load(Ordering::Relaxed),
        shots_denied: denied.load(Ordering::Relaxed),
    };
    for j in hub.join_all() {
        let report = j.report.expect("pipeline succeeded");
        if let Some(i) = j.name.strip_prefix('v').and_then(|s| s.parse::<usize>().ok())
        {
            assert_eq!(
                report.latency.count, frames,
                "{}: one e2e latency sample per frame",
                j.name
            );
            out.victim_p50[i] = report.latency.p50;
            out.victim_p99[i] = report.latency.p99;
            // every victim topic carries queue-wait percentiles too
            let t = report
                .topics
                .iter()
                .find(|t| t.name == format!("e9/{tag}/v{i}"))
                .expect("victim topic snapshot");
            assert_eq!(t.latency.count, frames);
            assert_eq!(t.delivered, frames);
        }
        if j.name == "flooder" {
            let t = report
                .topics
                .iter()
                .find(|t| t.name == flood_topic)
                .expect("flood topic snapshot");
            out.flood_leaky_drops = t.drops.qos_leaky;
            // conservation holds even for the abused tenant
            assert_eq!(t.pushed, t.delivered + t.dropped + t.in_flight);
        }
    }
    out
}

fn main() {
    let args = harness::BenchArgs::parse();
    // frames per victim at 60 fps — quick ≈ 0.8 s per phase
    let frames = args.frames_or(48, 300);

    harness::warm_models(&["ars_a_opt"]);
    // warm the global executor so the thread baseline is steady
    {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        s.invoke(&[&vec![0.1f32; 128 * 3]]).unwrap();
    }
    let baseline_threads = harness::process_threads();

    println!("E9: {VICTIMS} victims x {frames} live frames @60fps on {WORKERS} workers");
    let a = run_phase("base", frames, false, false);
    let b = run_phase("flood", frames, true, true);

    // bounded threads across both phases (hub pools are joined/dropped;
    // allow one hub width plus our app threads for teardown lag)
    if let (Some(before), Some(after)) = (baseline_threads, harness::process_threads())
    {
        let added = after.saturating_sub(before);
        assert!(
            added <= WORKERS + VICTIMS + SHOT_THREADS + 2,
            "expected O(workers) threads, got +{added}"
        );
    }

    assert!(
        b.flood_leaky_drops > 0,
        "the flooded leaky tenant must have shed frames"
    );
    assert!(b.shots_done > 0, "SingleShot tenants ran during the flood");

    for i in 0..VICTIMS {
        let (pa, pb) = (a.victim_p99[i], b.victim_p99[i]);
        // isolation criterion: < 20% p99 movement; the absolute 2 ms
        // slack absorbs µs-scale histogram-bucket jitter when the
        // unloaded p99 is itself only microseconds
        let bound = pa.mul_f64(1.2).max(pa + Duration::from_millis(2));
        println!(
            "  victim-{i}: p50 {:?} -> {:?}, p99 {:?} -> {:?} (bound {:?})",
            a.victim_p50[i], b.victim_p50[i], pa, pb, bound
        );
        assert!(
            pb <= bound,
            "victim-{i} p99 moved {pa:?} -> {pb:?} under flood (bound {bound:?})"
        );
    }
    println!(
        "  flood tenant: {} leaky drops (charged to the flooder, not the victims)",
        b.flood_leaky_drops
    );
    println!(
        "  singleshot tenants: {} served, {} admission-denied (quota 64 in flight)",
        b.shots_done, b.shots_denied
    );
    println!("e9_serving: OK (isolated p99, typed drops, bounded threads)");
}
