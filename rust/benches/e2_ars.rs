//! Regenerates the **E2 (Fig 3) measurements**: the ARS multi-modal
//! pipeline vs the conventional serial implementation.
//!
//! Paper numbers to compare shape against: memory −48%, live CPU −43%
//! (90.43% → 51.35%), batch rates +65.5% overall (46.0→59.4 (a),
//! 2.5→3.2 (b), 9.3→25.5 (c)); no frame drops in live mode.
//!
//! ```bash
//! cargo bench --bench e2_ars [-- --full]
//! ```

#[path = "harness.rs"]
mod harness;

use nnstreamer::apps::e2_ars::{self, ArsConfig};
use nnstreamer::baselines::control;
use nnstreamer::metrics::report::{f, Table};

fn main() {
    let args = harness::BenchArgs::parse();
    let windows = args.frames_or(160, 2000);
    harness::warm_models(&["ars_a_opt", "ars_b_opt", "ars_c_opt"]);

    // ---- batch processing rates (Fig 3's (a)/(b)/(c) rows) ----
    let cfg = ArsConfig {
        num_windows: windows,
        live: false,
        ..Default::default()
    };
    println!("E2 / Fig 3 — batch processing of {windows} sensor windows");
    let nns = e2_ars::run_nns(&cfg).expect("NNS ARS pipeline");
    let ctl = control::run_ars_control(windows, None).expect("ARS control");

    let mut t = Table::new(
        "E2: ARS batch processing rate (windows/s)",
        &["Stage", "Control", "NNStreamer", "Improvement", "Paper"],
    );
    let rows = [
        ("(a) activity", ctl.rate_a, nns.rate_a, "46.0 -> 59.4 (+29%)"),
        ("(b) fused", ctl.rate_b, nns.rate_b, "2.5 -> 3.2 (+28%)"),
        ("(c) audio", ctl.rate_c, nns.rate_c, "9.3 -> 25.5 (+174%)"),
    ];
    let mut geo = 1.0f64;
    for (name, c, n, paper) in rows {
        geo *= n / c;
        t.row(&[
            name.to_string(),
            f(c, 1),
            f(n, 1),
            format!("{:+.1}%", (n / c - 1.0) * 100.0),
            paper.to_string(),
        ]);
    }
    t.print();
    println!(
        "overall improvement (geomean): {:+.1}%  (paper: +65.5%)",
        (geo.powf(1.0 / 3.0) - 1.0) * 100.0
    );

    // ---- live-input CPU and memory (the paper's 30 fps live rows) ----
    let live_windows = args.frames_or(90, 900);
    let live_cfg = ArsConfig {
        num_windows: live_windows,
        live: true,
        rate: 30.0,
    };
    println!("\nlive input: {live_windows} windows at 30/s");
    let nns_live = e2_ars::run_nns(&live_cfg).expect("NNS live");
    let ctl_live =
        control::run_ars_control(live_windows, Some(30.0)).expect("control live");

    let mut t2 = Table::new(
        "E2: live 30/s input",
        &["Metric", "Control", "NNStreamer", "Paper"],
    );
    t2.row(&[
        "CPU (%)".into(),
        f(ctl_live.cpu_percent, 1),
        f(nns_live.cpu_percent, 1),
        "90.4 -> 51.4 (-43%)".into(),
    ]);
    t2.row(&[
        "Memory delta (MiB)".into(),
        f(ctl_live.mem_mib, 1),
        f(nns_live.mem_mib, 1),
        "448 -> 234 (-48%)".into(),
    ]);
    t2.row(&[
        "Dropped frames".into(),
        "0".into(),
        nns_live.dropped.to_string(),
        "both 0".into(),
    ]);
    t2.print();

    println!(
        "\ndevelopmental effort: the entire NNS application is {} pipeline lines \
         (paper: 'a dozen lines of code', one developer, a few hours)",
        nns.description_lines
    );
}
