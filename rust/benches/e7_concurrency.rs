//! **E7 — multi-tenant concurrency on a bounded worker pool.**
//!
//! 64 concurrent E1-shaped pipelines (camera → tee → queue → scale →
//! convert → normalize → I3 on CPU → decode → sink) run on a 4-worker
//! [`PipelineHub`]. The seed thread-per-element scheduler would have
//! spawned 64 × 10 = 640 OS threads; the hub must run the same fleet on
//! **O(workers)** threads, with sink output bit-identical to a
//! single-worker (serialized ≡ seed) run.
//!
//! ```bash
//! cargo bench --bench e7_concurrency             # quick
//! cargo bench --bench e7_concurrency -- --full   # paper-scale frames
//! cargo bench --bench e7_concurrency -- --frames 8
//! ```

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::{Pipeline, PipelineHub};

const PIPELINES: usize = 64;
const WORKERS: usize = 4;

use harness::process_threads;

/// Deterministic E1 single-branch pipeline (I3 on the CPU envelope —
/// blocking queue instead of e1's leaky one, so every frame arrives and
/// outputs are comparable bitwise).
fn e1_description(frames: u64) -> String {
    format!(
        "videotestsrc name=src pattern=ball width=320 height=240 framerate=120 \
         num-buffers={frames} is-live=false ! tee name=t t. ! queue ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=i3_opt accelerator=cpu ! \
         tensor_decoder mode=image_labeling ! tensor_sink name=out"
    )
}

/// Collect the sink payloads of a finished pipeline.
fn sink_bytes(p: &mut Pipeline) -> Vec<Vec<u8>> {
    let el = p.finished_element("out").expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| b.chunk().as_bytes_unaccounted().to_vec())
        .collect()
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(16, 120);

    harness::warm_models(&["i3_opt"]);

    // Reference: the same pipeline serialized on one worker — the
    // behavioral equivalent of the seed thread-per-element scheduler.
    let reference = {
        let hub = PipelineHub::with_workers(1);
        let p = Pipeline::parse(&e1_description(frames)).unwrap();
        hub.launch("ref", p).unwrap();
        let mut joined = hub.join_all();
        let j = joined.pop().unwrap();
        j.report.expect("reference run");
        let mut pipeline = j.pipeline;
        sink_bytes(&mut pipeline)
    };
    assert_eq!(reference.len(), frames as usize);

    let baseline_threads = process_threads();

    let hub = PipelineHub::with_workers(WORKERS);
    assert_eq!(hub.worker_count(), WORKERS);

    let t0 = Instant::now();
    for i in 0..PIPELINES {
        let p = Pipeline::parse(&e1_description(frames)).unwrap();
        hub.launch(format!("e1-{i}"), p).unwrap();
    }
    assert_eq!(hub.len(), PIPELINES);

    // Bounded-thread criterion: launching 64 pipelines (≈640 elements)
    // must add only the hub's workers, not a thread per element.
    let during_threads = process_threads();
    if let (Some(before), Some(during)) = (baseline_threads, during_threads) {
        let added = during.saturating_sub(before);
        println!(
            "threads: {before} before hub, {during} with {PIPELINES} pipelines \
             running (+{added}; {WORKERS} workers)"
        );
        assert!(
            added <= WORKERS + 2,
            "expected O(workers) threads, got +{added} for {PIPELINES} pipelines"
        );
        assert!(
            during < PIPELINES,
            "thread count must stay far below one-per-pipeline"
        );
    }

    let mut total_frames = 0u64;
    let mut agg_steps = 0u64;
    let mut agg_parks = 0u64;
    for j in hub.join_all() {
        let report = j.report.expect("pipeline succeeded");
        let seen = report.element("out").unwrap().buffers_in();
        assert_eq!(seen, frames, "{}: every frame must arrive", j.name);
        agg_steps += report.sched.steps;
        agg_parks += report.sched.parks_input + report.sched.parks_output;
        total_frames += seen;
        let mut pipeline = j.pipeline;
        assert_eq!(
            sink_bytes(&mut pipeline),
            reference,
            "{}: sink output must be bit-identical to the serialized run",
            j.name
        );
    }
    let wall = t0.elapsed();
    assert_eq!(
        hub.executor().live_tasks(),
        0,
        "joined hub must own no live element tasks"
    );

    println!(
        "E7: {PIPELINES} pipelines x {frames} frames on {WORKERS} workers \
         in {:.2} s — {:.1} frames/s aggregate, {agg_steps} steps, \
         {agg_parks} parks",
        wall.as_secs_f64(),
        total_frames as f64 / wall.as_secs_f64(),
    );
    println!(
        "executor totals: {} steps, {} wakeups, run-queue high-water {}",
        hub.executor().steps_executed(),
        hub.executor().wakeups(),
        hub.executor().run_queue_high_water(),
    );
    println!("e7_concurrency: OK (bounded threads, bit-identical outputs)");
}
