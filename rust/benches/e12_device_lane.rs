//! **E12 — async device lane vs blocking NPU dispatch.**
//!
//! 64 live-paced pipelines, each ending in a multi-ms NPU filter
//! (`i3_opt` with a 3 ms service override, 32 virtual device lanes),
//! race on a 4-worker hub two ways:
//!
//! * **block** — `dispatch=block`: every inference holds a worker for
//!   the full service window, so the pool (4 workers) is the ceiling;
//! * **async** — the default device lane: the filter submits, parks on
//!   the completion, and the worker moves on — the device (32 lanes)
//!   is the ceiling.
//!
//! Asserts the async lane reaches ≥4× the blocking throughput with
//! thread count O(workers) (not O(pipelines)), bit-identical sink
//! output, and live pacing riding the timer wheel rather than a
//! sleeping worker (timer-park counters).
//!
//! ```bash
//! cargo bench --bench e12_device_lane [-- --full] [-- --record]
//! ```
//!
//! `--record` writes `../artifacts/BENCH_e12_device_lane.json`
//! (the `make bench-smoke` target).

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use nnstreamer::devices::NpuSim;
use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::{Pipeline, PipelineHub};

const PIPELINES: usize = 64;
const SERVICE_MS: u64 = 3;
const NPU_LANES: usize = 32;

/// Hub pool size: 4 workers, or the `NNS_WORKERS` envelope override
/// (CI runs the smoke at the single-worker floor too).
fn workers() -> usize {
    std::env::var("NNS_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&w| w > 0)
        .unwrap_or(4)
}

fn launch_desc(frames: u64, dispatch: &str) -> String {
    // 250 fps live pacing: 4 ms between frames, so the source parks on
    // the timer wheel while the 3 ms service window is still credible.
    format!(
        "videotestsrc pattern=ball width=64 height=64 framerate=250 \
         num-buffers={frames} is-live=true ! \
         tensor_converter ! tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=i3_opt accelerator=npu dispatch={dispatch} ! \
         tensor_sink name=out"
    )
}

fn sink_payloads(p: &mut Pipeline) -> Vec<(u64, Vec<u8>)> {
    let el = p.finished_element("out").expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| (b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()))
        .collect()
}

#[derive(Default)]
struct FleetCounters {
    parks_timer: u64,
    timer_fires: u64,
    device_submits: u64,
    device_completions: u64,
}

struct FleetRun {
    wall_s: f64,
    /// Sink payloads of pipeline 0 (every pipeline is asserted equal).
    output: Vec<(u64, Vec<u8>)>,
    counters: FleetCounters,
    /// Process thread count sampled while the fleet was in flight.
    threads_during: Option<usize>,
}

fn run_fleet(frames: u64, dispatch: &str) -> FleetRun {
    let hub = PipelineHub::with_workers(workers());
    let t0 = Instant::now();
    for i in 0..PIPELINES {
        let p = Pipeline::parse(&launch_desc(frames, dispatch)).unwrap();
        hub.launch(format!("dl-{i}"), p).unwrap();
    }
    let threads_during = harness::process_threads();
    let mut counters = FleetCounters::default();
    let mut output: Option<Vec<(u64, Vec<u8>)>> = None;
    for j in hub.join_all() {
        let report = j.report.expect("fleet pipeline succeeded");
        counters.parks_timer += report.sched.parks_timer;
        counters.timer_fires += report.sched.timer_fires;
        counters.device_submits += report.sched.device_submits;
        counters.device_completions += report.sched.device_completions;
        let mut pipeline = j.pipeline;
        let payloads = sink_payloads(&mut pipeline);
        assert_eq!(
            payloads.len(),
            frames as usize,
            "{} ({dispatch}) lost frames",
            j.name
        );
        match &output {
            None => output = Some(payloads),
            Some(reference) => assert_eq!(
                &payloads, reference,
                "{} ({dispatch}) diverged from its siblings",
                j.name
            ),
        }
    }
    FleetRun {
        wall_s: t0.elapsed().as_secs_f64(),
        output: output.expect("at least one pipeline"),
        counters,
        threads_during,
    }
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(12, 24);
    let repeats = args.repeats.max(2);
    let record = std::env::args().any(|a| a == "--record");
    let workers = workers();

    harness::warm_models(&["i3_opt"]);
    let npu = NpuSim::global();
    npu.set_service_override("i3_opt", Duration::from_millis(SERVICE_MS));
    npu.set_parallelism(NPU_LANES);
    let baseline_threads = harness::process_threads();

    let (mut block_s, mut async_s) = (Vec::new(), Vec::new());
    let mut reference: Option<Vec<(u64, Vec<u8>)>> = None;
    let mut async_counters = FleetCounters::default();
    let mut threads_added = 0usize;
    let mut hwm_block = 0u64;
    for _ in 0..repeats {
        // block first: the NPU in-flight high-water mark is monotonic,
        // so the blocking ceiling is only readable before the first
        // async fleet raises it.
        let block = run_fleet(frames, "block");
        if hwm_block == 0 {
            hwm_block = npu.stats.in_flight_high_water();
        }
        let async_run = run_fleet(frames, "async");
        block_s.push(block.wall_s);
        async_s.push(async_run.wall_s);
        match &reference {
            None => reference = Some(block.output.clone()),
            Some(r) => assert_eq!(&block.output, r, "block run diverged across rounds"),
        }
        assert_eq!(
            async_run.output,
            block.output,
            "async device lane changed sink bytes"
        );
        if let (Some(b), Some(d)) = (baseline_threads, async_run.threads_during) {
            threads_added = threads_added.max(d.saturating_sub(b));
        }
        async_counters = async_run.counters;

        // Blocking dispatch never touches the completion path...
        assert_eq!(block.counters.device_submits, 0, "block dispatch used the device lane");
        // ...while the async lane submits every frame batch and drains
        // every completion (nothing leaked).
        assert!(async_counters.device_submits > 0, "async lane never submitted");
        assert_eq!(
            async_counters.device_submits, async_counters.device_completions,
            "device completions leaked"
        );
        // Live pacing parks on the timer wheel — at least once per
        // pipeline, in both dispatch modes.
        assert!(block.counters.parks_timer >= PIPELINES as u64);
        assert!(async_counters.parks_timer >= PIPELINES as u64);
    }

    let hwm_async = npu.stats.in_flight_high_water();
    // Zero-worker-cost dispatch: the device queue held more jobs than
    // there are workers — impossible when every job pins a worker.
    assert!(
        hwm_async > workers as u64,
        "async in-flight high-water {hwm_async} never exceeded the {workers}-worker pool \
         (blocking ceiling was {hwm_block})"
    );
    // Threads stay O(workers): the 64 parked pipelines are tasks, not
    // threads. Slack covers the NPU service thread and runtime helpers.
    assert!(
        threads_added <= workers + 8,
        "thread count scaled with pipelines: +{threads_added}"
    );

    let (bm, bs) = harness::mean_std(&block_s);
    let (am, asd) = harness::mean_std(&async_s);
    let total_frames = (PIPELINES as u64 * frames) as f64;
    let (bfps, afps) = (total_frames / bm, total_frames / am);
    let speedup = bm / am;
    println!(
        "E12: {PIPELINES} live pipelines x {frames} frames, {workers} workers, \
         {SERVICE_MS} ms NPU service on {NPU_LANES} lanes"
    );
    println!(
        "  dispatch=block   {} s   ({bfps:.0} frames/s)  in-flight hwm {hwm_block}",
        harness::pm(bm, bs, 3)
    );
    println!(
        "  dispatch=async   {} s   ({afps:.0} frames/s)  in-flight hwm {hwm_async}",
        harness::pm(am, asd, 3)
    );
    println!(
        "  speedup {speedup:.1}x   timer parks {} (fires {})   device submits {}",
        async_counters.parks_timer, async_counters.timer_fires, async_counters.device_submits
    );
    // The blocking ceiling is the worker pool, the async ceiling the
    // device lanes — so the achievable ratio shrinks as the pool grows.
    // 4x at the default 4-worker pool, halved headroom otherwise.
    let floor = (NPU_LANES as f64 / workers as f64 / 2.0).min(4.0);
    assert!(
        speedup >= floor,
        "async device lane reached only {speedup:.2}x the blocking throughput \
         (floor {floor:.1}x at {workers} workers)"
    );

    npu.clear_service_overrides();
    npu.set_parallelism(1);

    if record {
        let json = format!(
            "{{\n  \"bench\": \"e12_device_lane\",\n  \"pipeline\": \"live videotestsrc -> i3_opt on simulated NPU (3 ms service, 32 lanes)\",\n  \"pipelines\": {PIPELINES},\n  \"frames_per_pipeline\": {frames},\n  \"workers\": {workers},\n  \"fps_block\": {bfps:.1},\n  \"fps_async\": {afps:.1},\n  \"speedup\": {speedup:.2},\n  \"in_flight_hwm_block\": {hwm_block},\n  \"in_flight_hwm_async\": {hwm_async},\n  \"timer_parks\": {},\n  \"timer_fires\": {},\n  \"device_submits\": {},\n  \"threads_added\": {threads_added},\n  \"bit_identical_output\": true\n}}\n",
            async_counters.parks_timer, async_counters.timer_fires, async_counters.device_submits,
        );
        let path = if std::path::Path::new("../artifacts/manifest.txt").exists()
            && !std::path::Path::new("artifacts/manifest.txt").exists()
        {
            "../artifacts/BENCH_e12_device_lane.json"
        } else {
            "artifacts/BENCH_e12_device_lane.json"
        };
        std::fs::write(path, json).expect("write snapshot");
        println!("recorded {path}");
    }

    println!("e12_device_lane: OK (async lane {speedup:.1}x blocking, threads O(workers))");
}
