//! **E10 — fault-tolerant serving: supervised recovery without blast
//! radius.**
//!
//! One shared [`PipelineHub`] serves a latency-sensitive *victim* (live
//! source publishing tensors through a `qos=blocking` topic at
//! [`Priority::High`]) while a co-tenant *chaos* pipeline panics twice
//! under a deterministic [`FaultPlan`] and is brought back by the
//! supervisor's exponential backoff ([`RestartPolicy::OnFault`]). The
//! stall watchdog is armed for the whole run.
//!
//! Asserts that
//! * the chaos tenant recovers **within its backoff budget** — exactly
//!   the planned number of restarts, completing no earlier than the
//!   deterministic backoff floor and well inside the victim's stream,
//! * the victim's output is **bit-exact** (FNV-1a checksum) between the
//!   unloaded and chaos phases, with a clean EOS close-reason,
//! * the victim's p99 end-to-end latency moves by **< 20%** (plus a
//!   small absolute slack absorbing µs-scale bucket jitter),
//! * restart/fault counters surface in the supervised report.
//!
//! ```bash
//! cargo bench --bench e10_faults             # quick
//! cargo bench --bench e10_faults -- --full   # longer victim stream
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nnstreamer::pipeline::{
    FaultKind, FaultPlan, Pipeline, PipelineHub, Priority, RestartPolicy, StreamEnd,
};

const WORKERS: usize = 4;
const CHAOS_FAULTS: u32 = 2;
const CHAOS_BACKOFF: Duration = Duration::from_millis(5);

/// Latency-sensitive serving pipeline: live camera at 60 fps publishing
/// tensors through a blocking topic (every frame must arrive).
fn victim_desc(tag: &str, frames: u64) -> String {
    format!(
        "videotestsrc pattern=gradient num-buffers={frames} is-live=true ! \
         video/x-raw,format=RGB,width=32,height=32,framerate=60 ! \
         tensor_converter ! tensor_query_serversink topic=e10/{tag}/victim qos=blocking"
    )
}

/// Co-tenant that the chaos plan crashes mid-stream on its first
/// attempts; after the injected faults it runs the same chain cleanly.
fn chaos_desc() -> &'static str {
    "videotestsrc pattern=ball num-buffers=64 ! \
     video/x-raw,format=RGB,width=32,height=32,framerate=240 ! \
     tensor_converter name=conv ! fakesink name=out"
}

struct PhaseOut {
    p50: Duration,
    p99: Duration,
    checksum: u64,
    restarts: u32,
    faults: u32,
    recovery: Duration,
}

fn run_phase(tag: &str, frames: u64, chaos: bool) -> PhaseOut {
    let hub = Arc::new(PipelineHub::with_workers(WORKERS));
    // the watchdog is armed throughout: recovery must not depend on a
    // stall-free run, and a healthy phase must produce zero false kills
    hub.set_watchdog(Duration::from_millis(250));

    let sub = hub.subscribe_with_capacity(&format!("e10/{tag}/victim"), 32);
    let drain = std::thread::spawn(move || {
        let mut n = 0u64;
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a over every payload byte
        while let Ok(buf) = sub.recv() {
            n += 1;
            for chunk in &buf.chunks {
                for &b in chunk.as_bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
                }
            }
        }
        (n, h, sub.close_reason())
    });

    let p = Pipeline::parse(&victim_desc(tag, frames)).unwrap();
    hub.launch_with_priority("victim", p, Priority::High)
        .unwrap();

    let (restarts, faults, recovery) = if chaos {
        let attempts = Arc::new(AtomicUsize::new(0));
        let seen = attempts.clone();
        let t0 = Instant::now();
        hub.launch_supervised_with_priority(
            "chaos",
            move || {
                let mut p = Pipeline::parse(chaos_desc())?;
                if seen.fetch_add(1, Ordering::SeqCst) < CHAOS_FAULTS as usize {
                    p.set_fault_plan(FaultPlan::new().at("conv", 8, FaultKind::Panic));
                }
                Ok(p)
            },
            RestartPolicy::OnFault {
                max_restarts: CHAOS_FAULTS + 1,
                backoff: CHAOS_BACKOFF,
            },
            Priority::Low,
        )
        .unwrap();
        let join = hub.join_supervised("chaos").unwrap();
        let recovery = t0.elapsed();
        let report = join.report.expect("chaos tenant recovered, not quarantined");
        assert_eq!(report.restarts, CHAOS_FAULTS, "one restart per injected fault");
        assert_eq!(report.faults, CHAOS_FAULTS);
        assert_eq!(
            report.element("out").unwrap().buffers_in(),
            64,
            "the recovered attempt delivered its full stream"
        );
        (report.restarts, report.faults, recovery)
    } else {
        (0, 0, Duration::ZERO)
    };

    // the victim ends on its own frame budget; its drain follows
    let (delivered, checksum, reason) = drain.join().unwrap();
    assert_eq!(delivered, frames, "blocking qos delivered every victim frame");
    assert!(
        matches!(reason, Some(StreamEnd::Eos)),
        "victim stream must close with a clean EOS, got {reason:?}"
    );

    let join = hub.join_all().pop().expect("the victim pipeline");
    let report = join.report.expect("victim unaffected by the co-tenant");
    assert_eq!(report.latency.count, frames);
    PhaseOut {
        p50: report.latency.p50,
        p99: report.latency.p99,
        checksum,
        restarts,
        faults,
        recovery,
    }
}

fn main() {
    let args = harness::BenchArgs::parse();
    // frames per victim at 60 fps — quick ≈ 0.8 s per phase
    let frames = args.frames_or(48, 300);

    println!("E10: victim x {frames} live frames @60fps, chaos co-tenant on {WORKERS} workers");
    let a = run_phase("base", frames, false);
    let b = run_phase("chaos", frames, true);

    // the supervisor ran the deterministic schedule: 2 faults, 2
    // restarts, waiting at least backoff + 2*backoff before retries,
    // and the whole recovery fit inside the victim's live stream
    assert_eq!(b.restarts, CHAOS_FAULTS);
    assert_eq!(b.faults, CHAOS_FAULTS);
    let backoff_floor = CHAOS_BACKOFF + CHAOS_BACKOFF * 2;
    assert!(
        b.recovery >= backoff_floor,
        "recovery {:?} ran ahead of the deterministic backoff floor {:?}",
        b.recovery,
        backoff_floor
    );
    let stream_len = Duration::from_millis(frames * 1000 / 60);
    assert!(
        b.recovery < stream_len,
        "recovery {:?} must complete within the victim stream ({:?})",
        b.recovery,
        stream_len
    );
    println!(
        "  chaos tenant: {} faults, {} restarts, recovered in {:?} (floor {:?})",
        b.faults, b.restarts, b.recovery, backoff_floor
    );

    // bit-exact victim output across phases
    assert_eq!(
        a.checksum, b.checksum,
        "victim output must be bit-identical with a crashing co-tenant"
    );
    println!("  victim checksum: {:#018x} in both phases", a.checksum);

    // isolation criterion: < 20% p99 movement; the absolute 2 ms slack
    // absorbs µs-scale histogram-bucket jitter when the unloaded p99 is
    // itself only microseconds
    let bound = a.p99.mul_f64(1.2).max(a.p99 + Duration::from_millis(2));
    println!(
        "  victim: p50 {:?} -> {:?}, p99 {:?} -> {:?} (bound {:?})",
        a.p50, b.p50, a.p99, b.p99, bound
    );
    assert!(
        b.p99 <= bound,
        "victim p99 moved {:?} -> {:?} under chaos (bound {:?})",
        a.p99,
        b.p99,
        bound
    );
    println!("e10_faults: OK (recovery in budget, bit-exact victim, isolated p99)");
}
