//! **E8 — stream-endpoint (tensor-query) link overhead.**
//!
//! The E1 single-branch chain run two ways on the same 4-worker hub:
//!
//! * **direct** — one fused pipeline, every link an in-process inbox;
//! * **topic** — the chain split at the normalized-tensor link into two
//!   pipelines joined by a `tensor_query` topic (`serversink` →
//!   `serversrc`), the among-device composition of the follow-up paper.
//!
//! Asserts sink output **bit-identical** between the two, total thread
//! count O(workers) (the split doubles the pipeline count, not the
//! thread count), and prints the topic-link overhead.
//!
//! ```bash
//! cargo bench --bench e8_query             # quick
//! cargo bench --bench e8_query -- --full   # paper-scale frames
//! ```

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::{Pipeline, PipelineHub};

const WORKERS: usize = 4;

/// Head of the chain: camera to normalized f32 tensor.
fn head(frames: u64) -> String {
    format!(
        "videotestsrc name=src pattern=ball width=320 height=240 framerate=2400 \
         num-buffers={frames} is-live=false ! tee name=t t. ! queue ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255"
    )
}

/// Tail of the chain: I3 inference to a collecting sink.
const TAIL: &str = "tensor_filter framework=xla model=i3_opt accelerator=cpu ! \
                    tensor_decoder mode=image_labeling ! tensor_sink name=out";

/// The caps flowing on the split link (what the head's last transform
/// produces), announced by the subscriber via a trailing capsfilter.
const LINK_CAPS: &str = "other/tensor,dimension=3:64:64,type=float32,framerate=2400";

fn sink_bytes(p: &mut Pipeline) -> Vec<Vec<u8>> {
    let el = p.finished_element("out").expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| b.chunk().as_bytes_unaccounted().to_vec())
        .collect()
}

fn run_direct(frames: u64) -> (Vec<Vec<u8>>, f64) {
    let hub = PipelineHub::with_workers(WORKERS);
    let p = Pipeline::parse(&format!("{} ! {}", head(frames), TAIL)).unwrap();
    let t0 = Instant::now();
    hub.launch("direct", p).unwrap();
    let mut joined = hub.join_all();
    let wall = t0.elapsed().as_secs_f64();
    let j = joined.pop().unwrap();
    j.report.expect("direct run succeeded");
    let mut pipeline = j.pipeline;
    (sink_bytes(&mut pipeline), wall)
}

fn run_topic(frames: u64, round: usize) -> (Vec<Vec<u8>>, f64) {
    let topic = format!("e8/link-{round}");
    let hub = PipelineHub::with_workers(WORKERS);
    // back (subscriber) first: its subscription exists before the front
    // produces, so nothing is dropped and output stays bit-identical
    let back = Pipeline::parse(&format!(
        "tensor_query_serversrc topic={topic} max-buffers=8 ! {LINK_CAPS} ! {TAIL}"
    ))
    .unwrap();
    let front = Pipeline::parse(&format!(
        "{} ! tensor_query_serversink topic={topic}",
        head(frames)
    ))
    .unwrap();
    let t0 = Instant::now();
    hub.launch("back", back).unwrap();
    hub.launch("front", front).unwrap();
    let mut out = Vec::new();
    for j in hub.join_all() {
        j.report.expect("topic run succeeded");
        let mut pipeline = j.pipeline;
        if j.name == "back" {
            out = sink_bytes(&mut pipeline);
        }
    }
    (out, t0.elapsed().as_secs_f64())
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(64, 600);
    let repeats = args.repeats.max(3);

    harness::warm_models(&["i3_opt"]);

    let baseline_threads = harness::process_threads();
    let (reference, _) = run_direct(frames);
    assert_eq!(reference.len(), frames as usize, "direct run kept all frames");

    let mut direct_s = Vec::new();
    let mut topic_s = Vec::new();
    for round in 0..repeats {
        let (d, dt) = run_direct(frames);
        assert_eq!(d, reference, "direct runs are deterministic");
        direct_s.push(dt);
        let (q, qt) = run_topic(frames, round);
        assert_eq!(
            q, reference,
            "topic-linked sink output must be bit-identical to the direct link"
        );
        topic_s.push(qt);
    }

    // Bounded-thread criterion: splitting the chain into two pipelines
    // joined by a topic adds pipelines, not threads. All dedicated pools
    // are joined and dropped by now; only transient pool teardown may
    // lag a moment, so allow the current hub's width once.
    if let (Some(before), Some(after)) = (baseline_threads, harness::process_threads()) {
        assert!(
            after <= before + WORKERS,
            "topic link must not grow threads (before={before}, after={after})"
        );
    }

    let (dm, ds) = harness::mean_std(&direct_s);
    let (tm, ts) = harness::mean_std(&topic_s);
    println!("E8: {frames} frames x {repeats} runs on {WORKERS} workers");
    println!("  direct link   {} s", harness::pm(dm, ds, 3));
    println!("  topic link    {} s", harness::pm(tm, ts, 3));
    println!(
        "  topic-link overhead: {:+.1}% wall ({:.1} vs {:.1} frames/s)",
        (tm / dm - 1.0) * 100.0,
        frames as f64 / tm,
        frames as f64 / dm,
    );
    println!("e8_query: OK (bit-identical sink output, bounded threads)");
}
