//! Micro-benchmarks + ablations of the framework itself (not a paper
//! table, but the §Perf substrate): per-element throughput, scheduler
//! hop cost, zero-copy mux vs a deep-copy ablation, blocking vs leaky
//! queues, parser cost.
//!
//! ```bash
//! cargo bench --bench micro_elements
//! ```

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use nnstreamer::metrics::report::{f, Table};
use nnstreamer::pipeline::Pipeline;
use nnstreamer::tensor::{Buffer, Chunk};

fn run_fps(desc: &str, frames: u64) -> f64 {
    let mut p = Pipeline::parse(desc).expect(desc);
    let report = p.run().expect(desc);
    frames as f64 / report.wall.as_secs_f64()
}

fn main() {
    let args = harness::BenchArgs::parse();
    let n = args.frames_or(3000, 30000);
    let mut t = Table::new("micro: element throughput", &["case", "frames/s"]);

    // scheduler hop cost: source -> sink vs source -> 8 queues -> sink
    let direct = run_fps(
        &format!(
            "sensorsrc window=16 channels=1 rate=1000000 num-buffers={n} ! fakesink"
        ),
        n,
    );
    t.row(&["1 hop (src!sink)".into(), f(direct, 0)]);
    let hops = run_fps(
        &format!(
            "sensorsrc window=16 channels=1 rate=1000000 num-buffers={n} ! \
             queue ! queue ! queue ! queue ! queue ! queue ! queue ! queue ! fakesink"
        ),
        n,
    );
    t.row(&["9 hops (8 queues)".into(), f(hops, 0)]);

    // tee fanout
    let tee = run_fps(
        &format!(
            "sensorsrc window=16 channels=1 rate=1000000 num-buffers={n} ! tee name=t \
             t. ! queue ! fakesink t. ! queue ! fakesink t. ! queue ! fakesink"
        ),
        n,
    );
    t.row(&["tee x3 fanout".into(), f(tee, 0)]);

    // transform ops on video-sized tensors
    let nv = n / 10;
    let tr = run_fps(
        &format!(
            "videotestsrc pattern=gradient num-buffers={nv} ! \
             video/x-raw,format=RGB,width=320,height=240,framerate=1000000 ! \
             tensor_converter ! tensor_transform mode=typecast option=float32 ! \
             tensor_transform mode=arithmetic option=add:-127.5,div:127.5 ! fakesink"
        ),
        nv,
    );
    t.row(&["convert+cast+arith 320x240".into(), f(tr, 0)]);

    // videoscale
    let vs = run_fps(
        &format!(
            "videotestsrc pattern=gradient num-buffers={nv} ! \
             video/x-raw,format=RGB,width=640,height=480,framerate=1000000 ! \
             videoscale width=96 height=96 ! fakesink"
        ),
        nv,
    );
    t.row(&["videoscale 640x480->96".into(), f(vs, 0)]);

    // mux of 4 streams
    let mux = run_fps(
        &format!(
            "sensorsrc window=64 channels=1 rate=1000000 num-buffers={nv} seed=1 ! tensor_mux name=m sync-mode=slowest \
             sensorsrc window=64 channels=1 rate=1000000 num-buffers={nv} seed=2 ! m. \
             sensorsrc window=64 channels=1 rate=1000000 num-buffers={nv} seed=3 ! m. \
             sensorsrc window=64 channels=1 rate=1000000 num-buffers={nv} seed=4 ! m. \
             m. ! fakesink"
        ),
        nv,
    );
    t.row(&["tensor_mux x4 (slowest)".into(), f(mux, 0)]);
    t.print();

    // ---- ablation: zero-copy chunk bundling vs deep copy ----
    let frames = 20_000usize;
    let payload: Vec<f32> = (0..4096).map(|i| i as f32).collect();
    let bufs: Vec<Buffer> = (0..16)
        .map(|i| Buffer::from_f32(i, &payload))
        .collect();

    let t0 = Instant::now();
    for _ in 0..frames {
        let parts: Vec<Buffer> = bufs.iter().cloned().collect();
        let bundled = Buffer::bundle(parts).unwrap();
        std::hint::black_box(bundled.unbundle());
    }
    let zero_copy = frames as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..frames {
        // ablation: what mux would cost if it copied payloads
        let parts: Vec<Buffer> = bufs
            .iter()
            .map(|b| Buffer::single(b.pts_ns, Chunk::from_f32(b.chunk().as_f32().unwrap())))
            .collect();
        let bundled = Buffer::bundle(parts).unwrap();
        std::hint::black_box(bundled.unbundle());
    }
    let deep_copy = frames as f64 / t0.elapsed().as_secs_f64();

    let mut t2 = Table::new(
        "ablation: mux/demux bundling (16 tensors x 16 KiB)",
        &["strategy", "bundles/s", "speedup"],
    );
    t2.row(&["zero-copy (ours, §III)".into(), f(zero_copy, 0), f(zero_copy / deep_copy, 1)]);
    t2.row(&["deep-copy (ablation)".into(), f(deep_copy, 0), "1.0".into()]);
    t2.print();

    // ---- ablation: blocking vs leaky queue under an overloaded branch ----
    let slow_consumer = |leaky: bool| -> (f64, u64) {
        let desc = format!(
            "sensorsrc window=128 channels=3 rate=1000000 num-buffers=60 ! \
             queue max-size-buffers=2 {} name=q ! \
             tensor_filter framework=xla model=ars_a_opt ! fakesink name=out",
            if leaky { "leaky=downstream" } else { "" }
        );
        let mut p = Pipeline::parse(&desc).unwrap();
        let report = p.run().unwrap();
        (
            report.wall.as_secs_f64(),
            report.element("q").unwrap().dropped(),
        )
    };
    harness::warm_models(&["ars_a_opt"]);
    let (wall_block, d0) = slow_consumer(false);
    let (wall_leaky, d1) = slow_consumer(true);
    let mut t3 = Table::new(
        "ablation: queue policy with a slow model branch (60 frames)",
        &["policy", "wall (s)", "dropped"],
    );
    t3.row(&["blocking".into(), f(wall_block, 2), d0.to_string()]);
    t3.row(&["leaky=downstream".into(), f(wall_leaky, 2), d1.to_string()]);
    t3.print();

    // ---- parser cost ----
    let t0 = Instant::now();
    let reps = 2000;
    for _ in 0..reps {
        let g = nnstreamer::pipeline::parser::parse(
            "videotestsrc num-buffers=1 ! videoconvert format=RGB ! tee name=t \
             t. ! queue ! tensor_converter ! tensor_transform mode=normalize ! fakesink \
             t. ! queue ! fakesink",
        )
        .unwrap();
        std::hint::black_box(g.nodes.len());
    }
    println!(
        "\nparser: {:.0} pipelines/s (8-element description)",
        reps as f64 / t0.elapsed().as_secs_f64()
    );
}
