//! **E6: hot-path memory subsystem** — chunk-pool recycling, copy-on-write
//! in-place kernels and zero-copy output adoption, measured on the E1/I3
//! pipeline as bytes *allocated* per frame with pooling off vs on.
//!
//! ```bash
//! cargo bench --bench e6_memory [-- --full] [-- --record]
//! ```
//!
//! Method: the same pipeline runs twice with identical inputs. Case A
//! disables the [`ChunkPool`] (every chunk is a fresh heap allocation,
//! the pre-pool behavior); case B enables it, with one warmup run so the
//! size classes are populated and the measured run is steady state. The
//! `traffic::Snapshot.alloc` counter (fresh-allocation bytes) gives
//! bytes/frame for each case; sink payloads are asserted bit-identical,
//! so recycling is a pure allocator-traffic optimization.
//!
//! Acceptance (ISSUE 2): pooled steady state allocates >= 30% fewer
//! bytes/frame than unpooled. `--record` writes the measurement to
//! `../artifacts/BENCH_e6_memory.json` (the `make bench-smoke` target).

#[path = "harness.rs"]
mod harness;

use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::metrics::report::{f, Table};
use nnstreamer::metrics::traffic;
use nnstreamer::pipeline::Pipeline;
use nnstreamer::tensor::ChunkPool;

fn desc(frames: u64) -> String {
    format!(
        "videotestsrc pattern=ball num-buffers={frames} is-live=false ! \
         video/x-raw,format=RGB,width=128,height=128,framerate=100000 ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=i3_opt accelerator=cpu ! \
         tensor_sink name=out"
    )
}

struct Case {
    /// Sink payloads, per frame (for the bit-identity assertion).
    outputs: Vec<Vec<u8>>,
    traffic: traffic::Snapshot,
    fps: f64,
}

fn run_case(frames: u64) -> Case {
    let t0 = traffic::snapshot();
    let mut p = Pipeline::parse(&desc(frames)).expect("parse");
    let report = p.run().expect("run");
    let fps = report.fps("out");
    let seen = report.element("out").expect("sink stats").buffers_in();
    assert_eq!(seen, frames, "pipeline must deliver every frame");
    let sink = p
        .finished_element("out")
        .and_then(|el| el.as_any())
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    let outputs = sink
        .buffers
        .iter()
        .map(|b| b.chunk().as_bytes_unaccounted().to_vec())
        .collect();
    Case {
        outputs,
        traffic: traffic::since(t0),
        fps,
    }
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(96, 1000);
    let record = std::env::args().any(|a| a == "--record");

    // desktop measurement: no embedded-CPU envelope, real dispatch cost
    nnstreamer::nnfw::set_cpu_rate_flops(0);
    harness::warm_models(&["i3_opt"]);
    let pool = ChunkPool::global();

    println!("E6 — chunk-pool memory subsystem on the E1/I3 pipeline ({frames} frames per case)");

    // Case A: pooling off — every chunk is a fresh allocation.
    pool.set_enabled(false);
    pool.clear();
    let unpooled = run_case(frames);

    // Case B: pooling on — one warmup run populates the size classes,
    // then the measured run is steady state.
    pool.set_enabled(true);
    let _warmup = run_case(frames);
    let pooled = run_case(frames);

    assert_eq!(
        unpooled.outputs, pooled.outputs,
        "pooled sink output must be bit-identical to unpooled"
    );
    println!(
        "sink output bit-identical across {} frames ✓",
        pooled.outputs.len()
    );

    let per_frame = |t: &traffic::Snapshot| t.alloc as f64 / frames as f64;
    let a = per_frame(&unpooled.traffic);
    let b = per_frame(&pooled.traffic);
    let reduction = 1.0 - b / a.max(1e-9);

    let mut t = Table::new(
        "E6: bytes allocated per frame, pooling off vs on (i3_opt, CPU)",
        &[
            "case",
            "alloc B/frame",
            "pool-reuse B/frame",
            "in-place B/frame",
            "frames/s",
        ],
    );
    for (label, case) in [("unpooled", &unpooled), ("pooled", &pooled)] {
        t.row(&[
            label.to_string(),
            f(case.traffic.alloc as f64 / frames as f64, 0),
            f(case.traffic.pool_reuse as f64 / frames as f64, 0),
            f(case.traffic.inplace as f64 / frames as f64, 0),
            f(case.fps, 1),
        ]);
    }
    t.print();

    println!(
        "\nalloc reduction = {:.1}% (acceptance target >= 30%); steady-state reuse ratio = {:.1}%",
        reduction * 100.0,
        pooled.traffic.reuse_ratio() * 100.0
    );
    println!("pool retains {} KiB across size classes", pool.retained_bytes() / 1024);

    if record {
        let json = format!(
            "{{\n  \"bench\": \"e6_memory\",\n  \"pipeline\": \"E1/I3 (i3_opt, cpu)\",\n  \"frames_per_case\": {frames},\n  \"alloc_bytes_per_frame_unpooled\": {:.1},\n  \"alloc_bytes_per_frame_pooled\": {:.1},\n  \"alloc_reduction\": {:.4},\n  \"pool_reuse_bytes_per_frame\": {:.1},\n  \"inplace_bytes_per_frame\": {:.1},\n  \"fps_unpooled\": {:.2},\n  \"fps_pooled\": {:.2},\n  \"bit_identical_output\": true\n}}\n",
            a,
            b,
            reduction,
            pooled.traffic.pool_reuse as f64 / frames as f64,
            pooled.traffic.inplace as f64 / frames as f64,
            unpooled.fps,
            pooled.fps,
        );
        // same ./artifacts vs ../artifacts resolution as ModelRegistry
        let path = if std::path::Path::new("../artifacts/manifest.txt").exists()
            && !std::path::Path::new("artifacts/manifest.txt").exists()
        {
            "../artifacts/BENCH_e6_memory.json"
        } else {
            "artifacts/BENCH_e6_memory.json"
        };
        std::fs::write(path, json).expect("write snapshot");
        println!("recorded {path}");
    }

    assert!(
        reduction >= 0.30,
        "pooling must cut allocated bytes/frame by >= 30% (got {:.1}%)",
        reduction * 100.0
    );
}
