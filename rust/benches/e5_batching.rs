//! **E5: batched `tensor_filter` execution** — batch=1 vs batch=4/8 on the
//! E1 single-model pipeline (Fig 2's I3 branch), CPU accelerator with the
//! embedded envelope disabled so the measurement is the real per-dispatch
//! overhead being amortized.
//!
//! ```bash
//! cargo bench --bench e5_batching [-- --full]
//! ```
//!
//! Expected shape: throughput grows with the batch size because the
//! per-dispatch cost (executable launch + weight residency) is paid once
//! per stacked invocation; batch=4 should land at >= 1.3x the batch=1
//! frames/s. De-batched outputs are bit-identical to unbatched execution
//! (asserted by `tests/integration.rs`), so this is a pure-throughput
//! knob bounded by `latency-budget`.

#[path = "harness.rs"]
mod harness;

use nnstreamer::metrics::report::{f, Table};
use nnstreamer::pipeline::Pipeline;
use nnstreamer::runtime::ModelPool;

fn run_once(batch: usize, frames: u64) -> f64 {
    let desc = format!(
        "videotestsrc pattern=ball num-buffers={frames} is-live=false ! \
         video/x-raw,format=RGB,width=128,height=128,framerate=100000 ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=i3_opt accelerator=cpu \
           batch={batch} latency-budget=20 ! \
         tensor_decoder mode=image_labeling ! fakesink name=out"
    );
    let mut p = Pipeline::parse(&desc).expect("parse");
    let report = p.run().expect("run");
    let seen = report.element("out").expect("sink stats").buffers_in();
    assert_eq!(seen, frames, "batching must not drop or duplicate frames");
    seen as f64 / report.wall.as_secs_f64()
}

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(240, 2000);

    // desktop measurement: no embedded-CPU envelope, real dispatch cost
    nnstreamer::nnfw::set_cpu_rate_flops(0);
    harness::warm_models(&["i3_opt"]);

    println!("E5 — batched tensor_filter on the E1/I3 pipeline ({frames} frames per case)");
    let mut t = Table::new(
        "E5: batch size vs throughput (i3_opt, CPU dispatch)",
        &["batch", "frames/s", "speedup vs batch=1"],
    );

    let mut base = 0.0f64;
    let mut speedup4 = 0.0f64;
    for batch in [1usize, 4, 8] {
        let fps = run_once(batch, frames);
        if batch == 1 {
            base = fps;
        }
        if batch == 4 {
            speedup4 = fps / base.max(1e-9);
        }
        t.row(&[
            batch.to_string(),
            f(fps, 1),
            format!("{:.2}x", fps / base.max(1e-9)),
        ]);
        eprintln!("  done: batch={batch}");
    }
    t.print();

    println!(
        "\nspeedup(batch=4) = {speedup4:.2}x (acceptance target >= 1.30x)"
    );
    let pool = ModelPool::global().expect("pool");
    println!(
        "pool: i3_opt loads={} acquires={} (all cases shared one instance)",
        pool.loads("i3_opt"),
        pool.acquires("i3_opt")
    );
}
