//! Regenerates **Table I** (E1): multi-model pipelines on heterogeneous
//! resources — Control vs NNStreamer, I3/Y3 on the simulated NPU, C/I3 on
//! the CPU envelope, 1–3 models.
//!
//! ```bash
//! cargo bench --bench e1_table1            # quick (~1.5 min)
//! cargo bench --bench e1_table1 -- --full  # paper scale (100 s per case)
//! ```
//!
//! Expected *shape* (not absolute numbers — see DESIGN.md):
//!   * NNS single-model throughput > Control, with much lower app CPU;
//!   * two models on one NPU: per-model rates ≈ capacity split, near-zero
//!     sharing overhead;
//!   * CPU+NPU mixes: both rates virtually unaffected (< ~5% overhead).

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use nnstreamer::apps::e1::{run_case, E1Case, E1Config};
use nnstreamer::devices::NpuSim;
use nnstreamer::metrics::report::{f, Table};

/// Paper-calibrated NPU service times: I3 -> 28 fps ceiling, Y3 -> 10.8.
const I3_SERVICE_MS: f64 = 35.7;
const Y3_SERVICE_MS: f64 = 92.6;

fn main() {
    let args = harness::BenchArgs::parse();
    let frames = args.frames_or(120, 3000);

    harness::warm_models(&["i3_opt", "y3_opt", "i3_ref"]);
    let npu = NpuSim::global();
    npu.set_service_override("i3_opt", Duration::from_secs_f64(I3_SERVICE_MS / 1e3));
    npu.set_service_override("y3_opt", Duration::from_secs_f64(Y3_SERVICE_MS / 1e3));

    let cfg = E1Config {
        num_frames: frames,
        live: true,
        ..Default::default()
    };
    println!(
        "E1 / Table I — {} frames at {} fps live input (paper: 3000 @ 30)",
        cfg.num_frames, cfg.fps
    );

    let mut table = Table::new(
        "Table I: E1 multi-model pipelines (A311D analog)",
        &[
            "Configuration",
            "Throughput (fps)",
            "CPU (%)",
            "Mem (MiB)",
            "Improved",
        ],
    );

    // single-model NNS rates are the baselines for the paper's
    // improved-throughput formula
    let mut base: std::collections::HashMap<&str, f64> = Default::default();

    for case in E1Case::all() {
        let row = run_case(&cfg, case).expect(case.label());
        let fps_cell = row
            .fps
            .iter()
            .map(|v| f(*v, 1))
            .collect::<Vec<_>>()
            .join(", ");
        // paper formula: (sum_i fps_i / fps_single_i) / #HW
        let improved = match case {
            E1Case::NnsI3 => {
                base.insert("i3", row.fps[0]);
                String::from("-")
            }
            E1Case::NnsY3 => {
                base.insert("y3", row.fps[0]);
                String::from("-")
            }
            E1Case::NnsCpuI3 => {
                base.insert("c/i3", row.fps[0]);
                String::from("-")
            }
            E1Case::ControlI3 | E1Case::ControlY3 => String::from("-"),
            _ => {
                let branches = case.branches();
                let mut ratio = 0.0;
                let mut hw = std::collections::HashSet::new();
                for ((stem, on_npu), fps) in branches.iter().zip(&row.fps) {
                    let key = if *on_npu { *stem } else { "c/i3" };
                    ratio += fps / base.get(key).copied().unwrap_or(1.0);
                    hw.insert(*on_npu);
                }
                let v = (ratio / hw.len() as f64 - 1.0) * 100.0;
                format!("{v:+.1}%")
            }
        };
        table.row(&[
            row.label.clone(),
            fps_cell,
            f(row.cpu_percent, 1),
            f(row.mem_mib, 1),
            improved,
        ]);
        eprintln!("  done: {}", row.label);
    }
    table.print();

    let stats = &npu.stats;
    println!(
        "\nNPU totals: {} jobs, mean queue {:.1} ms, mean service {:.1} ms",
        stats.jobs(),
        stats.mean_queue().as_secs_f64() * 1e3,
        stats.mean_service().as_secs_f64() * 1e3
    );
    npu.clear_service_overrides();
}
